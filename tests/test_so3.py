"""SO(3) machinery: representation properties that NequIP/EquiformerV2
correctness rests on."""
import math

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev-only dep; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.models.gnn import so3

angles = st.tuples(st.floats(-3.1, 3.1), st.floats(-0.99, 0.99))


@pytest.mark.parametrize("l", range(7))
def test_wigner_orthogonal(l):
    rng = np.random.default_rng(l)
    a = jnp.asarray(rng.uniform(-np.pi, np.pi, (4,)))
    cb = jnp.asarray(rng.uniform(-1, 1, (4,)))
    D = np.asarray(so3.wigner_real(l, a, cb))
    eye = np.einsum("bij,bkj->bik", D, D)
    assert np.abs(eye - np.eye(2 * l + 1)).max() < 1e-4


@pytest.mark.parametrize("l", range(5))
def test_sph_harm_norm(l):
    rng = np.random.default_rng(7)
    r = rng.normal(size=(6, 3))
    r /= np.linalg.norm(r, axis=1, keepdims=True)
    y = np.asarray(so3.sph_harm_all(l, jnp.asarray(r))[l])
    want = math.sqrt((2 * l + 1) / (4 * math.pi))
    assert np.abs(np.linalg.norm(y, axis=-1) - want).max() < 1e-5


@given(angles)
@settings(max_examples=10, deadline=None)
def test_sph_harm_equivariance(ang):
    """Y(R r) = D(R) Y(r) with R extracted from the l=1 block."""
    alpha, cbeta = ang
    rng = np.random.default_rng(0)
    r = rng.normal(size=(5, 3))
    r /= np.linalg.norm(r, axis=1, keepdims=True)
    D1 = np.asarray(so3.wigner_real(1, jnp.asarray([alpha]),
                                    jnp.asarray([cbeta])))[0]
    M = np.array([[0., -1, 0], [0, 0, 1], [1, 0, 0]])   # xyz → (−y,z,x)
    R = np.linalg.inv(M) @ D1 @ M
    for l in range(4):
        D = np.asarray(so3.wigner_real(l, jnp.asarray([alpha]),
                                       jnp.asarray([cbeta])))[0]
        y = np.asarray(so3.sph_harm_all(l, jnp.asarray(r))[l])
        y_rot = np.asarray(so3.sph_harm_all(l, jnp.asarray(r @ R.T))[l])
        assert np.abs(y_rot - y @ D.T).max() < 1e-4


@pytest.mark.parametrize("path", [(1, 1, 0), (1, 1, 2), (2, 1, 1),
                                  (2, 2, 2), (3, 2, 3), (6, 2, 6),
                                  (6, 2, 5)])
def test_cg_equivariance(path):
    l1, l2, l3 = path
    C = so3.real_cg(l1, l2, l3)
    rng = np.random.default_rng(sum(path))
    x = rng.normal(size=(2 * l1 + 1,))
    y = rng.normal(size=(2 * l2 + 1,))
    alpha, cbeta = 0.83, -0.41
    ds = [np.asarray(so3.wigner_real(l, jnp.asarray([alpha]),
                                     jnp.asarray([cbeta])))[0]
          for l in (l1, l2, l3)]
    lhs = np.einsum("pqr,p,q->r", C, ds[0] @ x, ds[1] @ y)
    rhs = ds[2] @ np.einsum("pqr,p,q->r", C, x, y)
    assert np.abs(lhs - rhs).max() < 1e-5


def test_rotation_to_edge_frame_concentrates_m0():
    """The eSCN precondition: D(angles(r̂))ᵀ Y(r̂) has support only at m=0."""
    rng = np.random.default_rng(3)
    r = rng.normal(size=(10, 3))
    r /= np.linalg.norm(r, axis=1, keepdims=True)
    rh = jnp.asarray(r)
    al, cb = so3.rotation_angles(rh)
    for l in (1, 2, 4, 6):
        D = np.asarray(so3.wigner_real(l, al, cb))
        y = np.asarray(so3.sph_harm_all(l, rh)[l])
        rot = np.einsum("bmk,bm->bk", D, y)
        off = np.abs(np.delete(rot, l, axis=1)).max()
        assert off < 1e-4
        assert np.all(rot[:, l] > 0)


def test_m_truncation_index():
    idx = so3.m_truncation_index(2, 1)
    # l=0: m=0 → 0; l=1: m=-1,0,1 → 1,2,3; l=2: m=-1,0,1 → 5,6,7
    assert idx.tolist() == [0, 1, 2, 3, 5, 6, 7]
