"""Per-arch GNN smoke tests (reduced configs) + sampler + equivariance."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.graphs import erdos_renyi
from repro.graphs.sampler import fanout_sample, subgraph_budget
from repro.models.gnn import (GraphBatch, batch_from_graph, pad_graph_batch,
                              sage, pna, nequip, equiformer_v2, so3)
from repro.models.gnn.common import segment_agg, segment_softmax
from repro.train import adamw, constant_schedule

GNN_ARCHS = ["pna", "graphsage-reddit", "nequip", "equiformer-v2"]
_MODS = {"pna": pna, "graphsage-reddit": sage, "nequip": nequip,
         "equiformer-v2": equiformer_v2}


def _toy_batch(cfg, geometric, seed=0, n_classes=3):
    rng = np.random.default_rng(seed)
    g = erdos_renyi(48, 200, seed=seed + 1)
    x = rng.normal(size=(g.n, cfg.d_feat)).astype(np.float32)
    pos = rng.normal(size=(g.n, 3)).astype(np.float32) * 2 if geometric \
        else None
    out_kind = getattr(cfg, "out_kind", "node")
    if out_kind == "graph":
        labels = np.zeros(1, np.float32)
    else:
        labels = rng.integers(0, n_classes, g.n)
    return batch_from_graph(g, x, labels=labels, pos=pos)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_reduced_train_step(arch):
    """One forward/train step on CPU: output shapes + no NaNs."""
    cfg = get_arch(arch).config(reduced=True)
    mod = _MODS[arch]
    geometric = arch in ("nequip", "equiformer-v2")
    batch = _toy_batch(cfg, geometric, n_classes=getattr(cfg, "n_classes", 3))
    params = mod.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant_schedule(5e-3))
    state = opt.init(params)

    @jax.jit
    def step(p, st, b):
        loss, grads = jax.value_and_grad(mod.loss_fn)(p, b, cfg)
        p, st = opt.apply(grads, st, p)
        return p, st, loss

    losses = []
    for _ in range(4):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    out = mod.apply(params, batch, cfg)
    assert out.shape[0] == batch.n


@pytest.mark.parametrize("arch", ["nequip", "equiformer-v2"])
def test_rotation_invariance(arch):
    cfg = get_arch(arch).config(reduced=True)
    mod = _MODS[arch]
    rng = np.random.default_rng(2)
    g = erdos_renyi(40, 160, seed=3)
    x = rng.normal(size=(g.n, cfg.d_feat)).astype(np.float32)
    pos = rng.normal(size=(g.n, 3)).astype(np.float32) * 2
    b1 = batch_from_graph(g, x, labels=np.zeros(1, np.float32), pos=pos)
    D1 = np.asarray(so3.wigner_real(1, jnp.asarray([1.1]),
                                    jnp.asarray([0.4])))[0]
    M = np.array([[0., -1, 0], [0, 0, 1], [1, 0, 0]])
    R = np.linalg.inv(M) @ D1 @ M
    b2 = batch_from_graph(g, x, labels=np.zeros(1, np.float32),
                          pos=pos @ R.T)
    params = mod.init_params(cfg, jax.random.PRNGKey(4))
    o1 = mod.apply(params, b1, cfg)
    o2 = mod.apply(params, b2, cfg)
    scale = max(1e-3, float(jnp.abs(o1).max()))
    assert float(jnp.abs(o1 - o2).max()) / scale < 1e-4


def test_fanout_sampler_budget_and_correctness():
    g = erdos_renyi(500, 4000, seed=5)
    seeds = np.arange(16)
    fanout = (4, 3)
    sub = fanout_sample(g, seeds, fanout, seed=6)
    n_pad, e_pad = subgraph_budget(16, fanout)
    assert sub.src.shape == (e_pad,) and sub.node_ids.shape == (n_pad,)
    assert sub.seed_mask.sum() == 16
    # every sampled edge is a real edge of the graph
    real = set(zip(g.src.tolist(), g.dst.tolist()))
    valid = sub.src < sub.n_pad
    for s_l, d_l in zip(sub.src[valid], sub.dst[valid]):
        gs = int(sub.node_ids[s_l])
        gd = int(sub.node_ids[d_l])
        # message edge sender→receiver == (receiver follows sender): the
        # sampled neighbour pair (gd, gs) must be a real edge
        assert (gd, gs) in real
    # dst-sorted for sorted segment ops
    d_real = sub.dst[valid]
    assert np.all(np.diff(d_real) >= 0)


def test_segment_helpers():
    dst = jnp.asarray([0, 0, 1, 3, 3, 3])
    vals = jnp.asarray([[1.], [3.], [5.], [2.], [4.], [6.]])
    n = 4
    assert np.allclose(np.asarray(segment_agg(vals, dst, n, "mean"))[:2].T,
                       [[2.0, 5.0]])
    assert np.allclose(np.asarray(segment_agg(vals, dst, n, "max"))[3], 6.0)
    assert np.allclose(np.asarray(segment_agg(vals, dst, n, "min"))[3], 2.0)
    std3 = float(np.asarray(segment_agg(vals, dst, n, "std"))[3, 0])
    assert abs(std3 - np.std([2, 4, 6])) < 1e-5
    sm = np.asarray(segment_softmax(jnp.asarray([0., 0., 1., 1., 1., 1.]),
                                    dst, n))
    assert abs(sm[0] - 0.5) < 1e-6 and abs(sm[3] - 1 / 3) < 1e-6


def test_pad_graph_batch():
    g = erdos_renyi(30, 100, seed=7)
    b = batch_from_graph(g, np.ones((30, 4), np.float32),
                         labels=np.zeros(30, np.int64))
    bp = pad_graph_batch(b, 64, 512)
    assert bp.n == 64 and bp.src.shape == (512,)
    assert int(bp.node_mask.sum()) == 30
    # sentinel edges point at the dropped segment
    assert np.all(np.asarray(bp.src[200:]) == 64)
