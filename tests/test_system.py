"""End-to-end behaviour of the paper's system (Power-ψ vs baselines)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import erdos_renyi, powerlaw_configuration, load_dataset
from repro.core import (heterogeneous, homogeneous, build_operators,
                        power_psi, power_psi_fixed, power_nf, exact_psi,
                        build_pagerank_ops, pagerank, PsiService,
                        dense_operators)


@pytest.fixture(scope="module")
def small():
    g = erdos_renyi(300, 2100, seed=3)
    act = heterogeneous(g.n, seed=5)
    return g, act, build_operators(g, act)


def test_power_psi_matches_exact(small):
    g, act, ops = small
    res = power_psi(ops, tol=1e-10)
    psi_true, _ = exact_psi(g, act)
    rel = np.linalg.norm(res.psi - psi_true) / np.linalg.norm(psi_true)
    assert rel < 1e-5
    assert bool(res.converged)


def test_power_nf_matches_exact_and_costs_more(small):
    """Alg. 1 reaches the same answer with orders more mat-vecs (Fig. 4)."""
    g, act, ops = small
    nf = power_nf(ops, tol=1e-10, chunk=64)
    psi_true, _ = exact_psi(g, act)
    rel = np.linalg.norm(nf.psi - psi_true) / np.linalg.norm(psi_true)
    assert rel < 1e-5
    ps = power_psi(ops, tol=1e-10)
    assert nf.matvecs > 50 * int(ps.matvecs)


def test_homogeneous_equals_pagerank(small):
    """[10, Thm 5]: ψ(λ, μ const) == PageRank(α = μ/(λ+μ))."""
    g, _, _ = small
    act = homogeneous(g.n, lam=0.15, mu=0.85)
    ops = build_operators(g, act)
    res = power_psi(ops, tol=1e-12)
    pr = pagerank(build_pagerank_ops(g), alpha=0.85, tol=1e-12)
    assert np.abs(np.asarray(res.psi) - np.asarray(pr.pi)).max() < 1e-6


def test_truncation_bound_eq19(small):
    """δ_t ≤ ε_t·‖B‖/N for every iteration t (Eq. 19)."""
    g, act, ops = small
    n_iter = 25
    _, _, gaps = power_psi_fixed(ops, n_iter)
    psis = [np.asarray(power_psi_fixed(ops, t)[0]) for t in range(1, n_iter)]
    for t in range(1, len(psis)):
        delta = np.abs(psis[t] - psis[t - 1]).sum()
        eps = float(gaps[t])              # ‖s_t − s_{t−1}‖₁
        bound = eps * float(ops.b_norm) / g.n
        assert delta <= bound * (1 + 1e-3) + 1e-12


def test_dense_operator_oracle(small):
    """Edge-form push equals the dense matrix product."""
    g, act, ops = small
    A, B, c, d = dense_operators(g, act)
    s = np.random.default_rng(0).uniform(size=g.n)
    want_sa = s @ A
    got_sa = np.asarray(ops.left_matvec(jnp.asarray(s, jnp.float32)))
    assert np.abs(want_sa - got_sa).max() < 1e-4
    want_psi = (s @ B + d) / g.n
    got_psi = np.asarray(ops.psi_epilogue(jnp.asarray(s, jnp.float32)))
    assert np.abs(want_psi - got_psi).max() < 1e-6


def test_warm_start_converges_faster(small):
    g, act, ops = small
    cold = power_psi(ops, tol=1e-9)
    act2 = heterogeneous(g.n, seed=5)
    act2.mu[:10] *= 1.05
    ops2 = build_operators(g, act2)
    warm = power_psi(ops2, tol=1e-9, s0=cold.s)
    cold2 = power_psi(ops2, tol=1e-9)
    assert int(warm.iterations) < int(cold2.iterations)


def test_psi_service_updates_and_ranks():
    g = erdos_renyi(120, 700, seed=9)
    act = heterogeneous(g.n, seed=1)
    svc = PsiService(g, act, tol=1e-9)
    top, scores = svc.top_k(5)
    assert scores.shape == (5,) and np.all(np.diff(scores) <= 0)
    u = int(top[-1])
    before = svc.scores()[u]
    svc.update_activity(np.asarray([u]), lam=np.asarray([5.0]))
    after = svc.scores()[u]
    assert after > before        # posting more raises own influence


def test_dataset_standins_match_table_ii():
    g = load_dataset("dblp")
    assert g.n == 12_591 and g.m == 49_743
    # heavy-tailed: max in-degree far above mean
    assert g.in_degree.max() > 20 * max(1.0, g.in_degree.mean())


def test_dangling_nodes_are_safe():
    # node 4 follows nobody (zero row in A) — must not produce NaN
    from repro.graphs.structure import Graph
    g = Graph(5, np.array([0, 1, 2], np.int32), np.array([1, 2, 0], np.int32))
    act = heterogeneous(5, seed=0)
    ops = build_operators(g, act)
    res = power_psi(ops, tol=1e-10)
    assert np.all(np.isfinite(np.asarray(res.psi)))
    psi_true, _ = exact_psi(g, act)
    assert np.abs(np.asarray(res.psi) - psi_true).max() < 1e-5
