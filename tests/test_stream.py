"""Streaming ingestion subsystem: event log determinism, estimator
convergence/fixed points, coalesced O(Δ) ingest parity against batch
recomputation for all three serving targets, unfollow tombstones,
freshness policy/certification, and the serving-layer satellites
(activity floor, empty-delta fast paths, edge removal)."""
import numpy as np
import pytest

from repro.core import (Activity, HostOperators, PsiService, exact_psi,
                        heterogeneous, homogeneous, make_engine)
from repro.core.activity import RATE_FLOOR
from repro.graphs import erdos_renyi, powerlaw_configuration
from repro.graphs.structure import Graph
from repro.stream import (Follow, FreshnessPolicy, FreshnessReport, Post,
                          RateEstimator, Repost, StreamIngestor, TenantEvent,
                          Unfollow, burst_stream, flash_crowd_stream,
                          poisson_stream, tenant_interleave)


def cold_activity(n: int) -> Activity:
    return Activity(np.full(n, RATE_FLOOR), np.full(n, RATE_FLOOR))


def batch_psi(graph, activity, *, tol=1e-9):
    """From-scratch reference solve — the parity oracle."""
    return np.asarray(make_engine("reference", graph=graph,
                                  activity=activity).run(tol=tol).psi)


# --------------------------------------------------------------------- #
# Event log
# --------------------------------------------------------------------- #
def test_replay_log_is_deterministic_and_reiterable():
    act = heterogeneous(16, seed=3)
    a = poisson_stream(act, 50.0, seed=9)
    b = poisson_stream(act, 50.0, seed=9)
    assert len(a) > 0 and list(a) == list(b)
    assert list(a) == list(a)                      # re-iteration is identical
    ts = [ev.t for ev in a]
    assert ts == sorted(ts)
    counts = a.counts()
    assert set(counts) == {"Post", "Repost"}


def test_flash_crowd_contains_follows_and_tombstones():
    g = powerlaw_configuration(100, 500, seed=4)
    act = heterogeneous(100, seed=5)
    log = flash_crowd_stream(g, act, 30.0, new_followers=20, churn=0.5,
                             seed=6)
    c = log.counts()
    assert c.get("Follow", 0) == 20
    assert c.get("Unfollow", 0) == 10
    # every tombstone targets an edge a Follow created
    followed = {(e.follower, e.leader) for e in log
                if isinstance(e, Follow)}
    for e in log:
        if isinstance(e, Unfollow):
            assert (e.follower, e.leader) in followed


def test_tenant_interleave_merges_by_time():
    act = heterogeneous(8, seed=1)
    log = tenant_interleave({"a": poisson_stream(act, 20.0, seed=2),
                             "b": poisson_stream(act, 20.0, seed=3)})
    ts = [ev.t for ev in log]
    assert ts == sorted(ts)
    assert {ev.tenant for ev in log} == {"a", "b"}


# --------------------------------------------------------------------- #
# Estimator: ground-truth rates are fixed points of generate → estimate
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("regime", ["heterogeneous", "homogeneous"])
def test_estimator_recovers_generator_rates(regime):
    n = 6
    truth = (heterogeneous(n, seed=11, low=0.2, high=1.0)
             if regime == "heterogeneous" else homogeneous(n))
    horizon = 30_000 / float(truth.total.sum())
    log = poisson_stream(truth, horizon, seed=12)
    est = RateEstimator(n, half_life=horizon)
    for ev in log:
        est.observe(ev)
    lam, mu = est.rates(horizon)
    err = (np.abs(lam - truth.lam).sum()
           + np.abs(mu - truth.mu).sum()) / float(truth.total.sum())
    assert err <= 0.05


def test_estimator_cold_start_floor_and_dirty_drain():
    est = RateEstimator(4, half_life=10.0)
    lam, mu = est.rates(0.0)
    assert np.all(lam == RATE_FLOOR) and np.all(mu == RATE_FLOOR)
    assert est.dirty.size == 0 and est.pending_mass() == 0.0
    est.observe(Post(1.0, 2))
    est.observe(Repost(1.5, 2))
    est.observe(Post(2.0, 0))
    assert est.dirty.tolist() == [0, 2]
    assert est.pending_mass(2.0) > 0.0
    mass_before = est.pending_mass(2.0)
    users, lam_d, mu_d, mass = est.drain(2.0)
    assert users.tolist() == [0, 2]
    assert np.all(lam_d >= RATE_FLOOR) and np.all(mu_d >= RATE_FLOOR)
    assert mass == pytest.approx(mass_before)   # pre-sync mass rides along
    # drained → synced: dirty set clears and mass drops to zero
    assert est.dirty.size == 0 and est.pending_mass(2.0) == 0.0
    empty, _, _, zero = est.drain()
    assert empty.size == 0 and zero == 0.0


def test_estimator_validation():
    with pytest.raises(ValueError, match="half_life"):
        RateEstimator(4, half_life=0.0)
    with pytest.raises(ValueError, match="floor"):
        RateEstimator(4, floor=0.0)
    est = RateEstimator(4)
    with pytest.raises(TypeError, match="Post/Repost"):
        est.observe(Follow(0.0, 1, 2))
    with pytest.raises(ValueError, match="out of range"):
        est.observe(Post(0.0, 7))


def test_estimator_half_life_tracks_burst():
    """A short half-life follows the burst up; the estimate at burst end
    exceeds the stationary rate."""
    n = 4
    truth = Activity(np.full(n, 0.5), np.full(n, 0.5))
    horizon = 600.0
    log = burst_stream(truth, horizon, burst_users=np.asarray([1]),
                       burst_factor=10.0, seed=7)
    est = RateEstimator(n, half_life=20.0)
    for ev in log:
        if ev.t <= 2 * horizon / 3:            # stop at the burst window end
            est.observe(ev)
    lam, _ = est.rates(2 * horizon / 3)
    assert lam[1] > 2.0                        # way above the base 0.5
    assert lam[0] < 1.5                        # non-burst users stay near base


# --------------------------------------------------------------------- #
# Satellite: Activity floor / validation
# --------------------------------------------------------------------- #
def test_activity_accepts_silent_users_and_floors_them():
    act = Activity(np.asarray([0.0, 0.5]), np.asarray([0.0, 0.5]))
    assert act.total[0] == 0.0                  # representable (masked c/d)
    fl = act.floored()
    assert np.all(fl.lam > 0) and np.all(fl.mu > 0)
    assert fl.lam[1] == 0.5                     # clamp only lifts zeros
    with pytest.raises(ValueError, match="floor"):
        act.floored(0.0)
    with pytest.raises(ValueError, match="finite"):
        Activity(np.asarray([np.nan]), np.asarray([1.0]))


# --------------------------------------------------------------------- #
# Satellite: HostOperators edge removal is exact
# --------------------------------------------------------------------- #
def test_host_remove_edges_matches_rebuild():
    g = erdos_renyi(40, 200, seed=13)
    act = heterogeneous(40, seed=14)
    host = HostOperators.from_graph(g, act)
    rng = np.random.default_rng(15)
    drop = rng.permutation(g.m)[:50]
    # include every leader of node src[drop[0]] so one follower hits w == 0
    j = int(g.src[drop[0]])
    extra = np.nonzero(g.src == j)[0]
    drop = np.unique(np.concatenate([drop, extra]))
    removed_src, removed_dst = host.remove_edges(g.src[drop], g.dst[drop])
    assert removed_src.size == drop.size
    keep = np.setdiff1d(np.arange(g.m), drop)
    ref = HostOperators.from_graph(Graph(g.n, g.src[keep], g.dst[keep]), act)
    np.testing.assert_array_equal(host.src_by_src, ref.src_by_src)
    np.testing.assert_array_equal(host.dst_by_dst, ref.dst_by_dst)
    np.testing.assert_allclose(host.w, ref.w, rtol=0, atol=0)
    np.testing.assert_allclose(host.row_lam, ref.row_lam, rtol=0, atol=0)
    assert host.w[j] == 0.0                    # exactly zero, no residue
    # absent pairs are ignored
    again = host.remove_edges(removed_src[:3], removed_dst[:3])
    assert again[0].size == 0


# --------------------------------------------------------------------- #
# Satellite: empty-delta fast paths
# --------------------------------------------------------------------- #
def test_service_empty_delta_is_a_true_noop():
    g = erdos_renyi(60, 240, seed=16)
    svc = PsiService(g, heterogeneous(60, seed=17), tol=1e-8)
    svc.scores()
    cache = svc._cache
    ops = svc.engine.ops
    svc.update_activity(np.empty(0, np.int64))
    svc.add_edges(np.empty(0, np.int32), np.empty(0, np.int32))
    svc.remove_edges(np.empty(0, np.int32), np.empty(0, np.int32))
    assert svc._cache is cache                 # ranking epoch untouched
    assert svc.engine.ops is ops               # HostOperators not re-uploaded
    assert not svc.stale


def test_fleet_empty_activity_patch_keeps_tenant_clean():
    from repro.serving import TenantFleet
    g = erdos_renyi(50, 200, seed=18)
    fleet = TenantFleet(backend="dense", tol=1e-7)
    fleet.admit("t0", g, heterogeneous(50, seed=19))
    fleet.solve()
    epoch = fleet.stats("t0")["epoch"]
    fleet.patch_activity("t0", np.empty(0, np.int64))
    assert fleet.stats("t0")["epoch"] == epoch
    assert fleet.solve() == 0                  # nothing dirty, no lanes run


# --------------------------------------------------------------------- #
# Deferred resolve + edge removal on PsiService
# --------------------------------------------------------------------- #
def test_service_deferred_patches_serve_stale_then_resolve():
    g = erdos_renyi(60, 240, seed=20)
    act = heterogeneous(60, seed=21)
    svc = PsiService(g, act, tol=1e-9)
    before = svc.scores().copy()
    svc.update_activity(np.asarray([3]), lam=np.asarray([5.0]),
                        resolve=False)
    assert svc.stale
    np.testing.assert_array_equal(svc.scores(), before)   # stale by design
    svc.resolve()
    assert not svc.stale
    lam2 = act.lam.copy()
    lam2[3] = 5.0
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_service_remove_edges_reaches_filtered_fixed_point(backend):
    g = erdos_renyi(50, 220, seed=22)
    act = heterogeneous(50, seed=23)
    svc = PsiService(g, act, tol=1e-9, backend=backend)
    svc.scores()
    rng = np.random.default_rng(24)
    drop = rng.permutation(g.m)[:30]
    svc.remove_edges(g.src[drop], g.dst[drop])
    keep = np.setdiff1d(np.arange(g.m), drop)
    psi_true, _ = exact_psi(Graph(g.n, g.src[keep], g.dst[keep]), act)
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6
    assert svc.graph.m == g.m - drop.size


# --------------------------------------------------------------------- #
# Ingest → resolve parity vs from-scratch batch (acceptance criterion)
# --------------------------------------------------------------------- #
def test_ingest_service_parity_flash_crowd():
    n, m = 200, 1_200
    g = powerlaw_configuration(n, m, seed=25)
    truth = heterogeneous(n, seed=26)
    horizon = 1_500 / float(truth.total.sum())
    log = flash_crowd_stream(g, truth, horizon, new_followers=24, churn=0.5,
                             seed=27)
    svc = PsiService(g, cold_activity(n), tol=1e-9)
    ing = StreamIngestor(svc, half_life=horizon / 2,
                         policy=FreshnessPolicy(coalesce=32,
                                                resolve_every=400))
    rep = ing.ingest(log)
    assert rep.resolves >= 2 and rep.events_total == len(log)
    assert rep.staleness_events == 0           # final resolve = fully fresh
    # replay + resolve == from-scratch solve on the final state
    psi_batch = batch_psi(svc.graph, svc.engine.activity)
    assert np.abs(svc.scores() - psi_batch).max() <= 1e-6
    # the graph actually churned: follows added, tombstones removed
    assert svc.graph.m != g.m
    # the estimator's synced mirror is exactly what the target serves
    # (each user's rate is the estimate at its last drain time — re-querying
    # the estimator *now* would re-decay, so compare the mirror, not rates())
    est = ing.estimator()
    assert est.dirty.size == 0 and est.pending_mass() == 0.0
    served = svc.engine.activity
    np.testing.assert_allclose(served.lam, est._synced[0], rtol=1e-12)
    np.testing.assert_allclose(served.mu, est._synced[1], rtol=1e-12)


def test_ingest_fleet_routes_tenant_events():
    from repro.serving import TenantFleet
    tenants = {}
    for k, tid in enumerate(("alpha", "beta")):
        g = erdos_renyi(64, 300, seed=30 + k)
        tenants[tid] = (g, heterogeneous(64, seed=40 + k))
    fleet = TenantFleet(backend="dense", tol=1e-8)
    for tid, (g, act) in tenants.items():
        fleet.admit(tid, g, cold_activity(g.n))
    horizon = 60.0
    log = tenant_interleave({
        tid: flash_crowd_stream(g, act, horizon, new_followers=10,
                                churn=0.4, seed=50 + i)
        for i, (tid, (g, act)) in enumerate(tenants.items())})
    ing = StreamIngestor(fleet, half_life=horizon / 2,
                         policy=FreshnessPolicy(coalesce=32,
                                                resolve_every=300))
    ing.ingest(log)
    for tid in tenants:
        g_final = fleet._rec(tid).host.graph()
        act_final = fleet.activity(tid)
        psi_batch = batch_psi(g_final, act_final, tol=1e-8)
        assert np.abs(fleet.psi(tid) - psi_batch).max() <= 1e-6
    # per-tenant estimators are independent lanes
    assert ing.estimator("alpha") is not ing.estimator("beta")
    with pytest.raises(TypeError, match="TenantEvent"):
        ing.submit(Post(99.0, 1))
    with pytest.raises(KeyError):
        ing.submit(TenantEvent("nope", Post(99.0, 1)))


def test_ingest_async_driver_between_runs_parity():
    from repro.asyncexec import AsyncPsiDriver
    n, m = 150, 900
    g = powerlaw_configuration(n, m, seed=33)
    truth = heterogeneous(n, seed=34)
    horizon = 800 / float(truth.total.sum())
    log = flash_crowd_stream(g, truth, horizon, new_followers=16, churn=0.5,
                             seed=35)
    drv = AsyncPsiDriver(g, cold_activity(n), num_chunks=3, tau=1)
    ing = StreamIngestor(drv, half_life=horizon / 2,
                         policy=FreshnessPolicy(coalesce=32,
                                                resolve_every=250),
                         resolve_opts=dict(tol=1e-9))
    rep = ing.ingest(log)
    assert rep.resolves >= 2
    psi_batch = batch_psi(drv.host.graph(), drv.host.activity())
    assert np.abs(ing.psi() - psi_batch).max() <= 1e-6


def test_ingest_rejects_unsupported_target():
    with pytest.raises(TypeError, match="unsupported"):
        StreamIngestor(object())


# --------------------------------------------------------------------- #
# Tombstone netting + freshness semantics
# --------------------------------------------------------------------- #
def test_unfollow_nets_against_pending_follow_in_window():
    g = erdos_renyi(30, 120, seed=36)
    act = heterogeneous(30, seed=37)
    svc = PsiService(g, act, tol=1e-8)
    svc.scores()
    cache = svc._cache
    ing = StreamIngestor(svc, policy=FreshnessPolicy(coalesce=100,
                                                     resolve_every=None))
    # a brand-new edge followed then unfollowed inside one window
    existing = set(zip(g.src.tolist(), g.dst.tolist()))
    s, d = next((a, b) for a in range(30) for b in range(30)
                if a != b and (a, b) not in existing)
    ing.submit(Follow(1.0, s, d))
    ing.submit(Unfollow(2.0, s, d))
    ing.flush()
    assert svc.graph.m == g.m                  # netted out: nothing applied
    assert svc._cache is cache                 # and nothing invalidated
    # unfollow → follow of an existing edge nets to the plain (dup) insert
    s0, d0 = int(g.src[0]), int(g.dst[0])
    ing.submit(Unfollow(3.0, s0, d0))
    ing.submit(Follow(4.0, s0, d0))
    ing.flush()
    assert svc.graph.m == g.m
    # a plain tombstone of an existing edge removes it
    ing.submit(Unfollow(5.0, s0, d0))
    ing.flush()
    assert svc.graph.m == g.m - 1


def test_freshness_policy_and_certification():
    g = erdos_renyi(40, 160, seed=38)
    truth = heterogeneous(40, seed=39)
    svc = PsiService(g, cold_activity(40), tol=1e-8)
    ing = StreamIngestor(svc, half_life=50.0,
                         policy=FreshnessPolicy(coalesce=10,
                                                resolve_every=50))
    log = poisson_stream(truth, 120 / float(truth.total.sum()), seed=40)
    ing.ingest(log, resolve_at_end=False)
    rep = ing.freshness()
    assert isinstance(rep, FreshnessReport)
    assert rep.events_total == len(log)
    assert rep.resolves == len(log) // 50      # the event trigger fired
    assert rep.events_unresolved < 50
    assert rep.events_buffered == 0            # ingest() always flushes
    # staleness bounds: lax passes, strict forces a resolve
    assert rep.certify(max_events=50)
    assert not rep.certify(max_events=0) or rep.events_unresolved == 0
    before = ing.resolves
    ing.top_k(5, max_events=0)                 # demand perfectly fresh
    assert ing.resolves == before + (1 if rep.events_unresolved else 0)
    assert ing.freshness().certify(max_events=0)
    # churn was tracked between resolves
    assert all(0.0 <= c <= 1.0 for c in ing.churn_history)


def test_query_driven_first_resolve_updates_freshness_accounting():
    """A query the target can only answer by solving (never resolved yet)
    must route through the ingestor's resolve() so the freshness report
    describes the ranking actually served."""
    from repro.asyncexec import AsyncPsiDriver
    g = erdos_renyi(40, 160, seed=42)
    truth = heterogeneous(40, seed=43)
    drv = AsyncPsiDriver(g, cold_activity(40), num_chunks=3, tau=1)
    ing = StreamIngestor(drv, half_life=20.0,
                         policy=FreshnessPolicy(coalesce=8,
                                                resolve_every=None),
                         resolve_opts=dict(tol=1e-9))
    log = poisson_stream(truth, 60 / float(truth.total.sum()), seed=44)
    ing.ingest(log, resolve_at_end=False)
    assert ing.resolves == 0
    ing.top_k(5)                               # no bounds — but never solved
    assert ing.resolves == 1
    rep = ing.freshness()
    assert rep.events_unresolved == 0 and rep.certify(max_events=0)
    before = ing.resolves
    ing.top_k(5, max_events=0)                 # already fresh: no extra run
    assert ing.resolves == before


def test_dirty_mass_trigger_resolves():
    g = erdos_renyi(20, 80, seed=41)
    svc = PsiService(g, cold_activity(20), tol=1e-8)
    ing = StreamIngestor(
        svc, half_life=10.0,
        policy=FreshnessPolicy(coalesce=4, resolve_every=None,
                               max_dirty_mass=0.5))
    # a hot user: rate estimates rocket past the floor → mass crosses 0.5
    for k in range(40):
        ing.submit(Post(0.1 * (k + 1), user=3))
    assert ing.resolves >= 1
    rep = ing.freshness()
    assert rep.dirty_mass <= 0.5 or rep.events_unresolved == 0
