"""Checkpointing + data pipeline + fault-tolerance units."""
import os
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint
from repro.data import TokenPipeline, PsiWeightedSampler


def test_checkpoint_roundtrip_and_gc():
    tree = dict(a=jnp.arange(6).reshape(2, 3),
                nested=dict(b=jnp.ones((4,)) * 3),
                lst=[jnp.zeros((2,)), jnp.asarray(7)])
    with tempfile.TemporaryDirectory() as d:
        for step in (0, 10, 20, 30):
            checkpoint.save(d, step, tree, keep=2)
        assert checkpoint.all_steps(d) == [20, 30]
        got = checkpoint.restore(d, 30, tree)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(got["lst"][0]),
                                      np.zeros((2,)))


def test_checkpoint_torn_write_is_invisible():
    """A *.tmp directory (mid-write crash) must never be listed."""
    tree = dict(x=jnp.ones((3,)))
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 5, tree)
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        with open(os.path.join(d, "step_00000009.tmp", "host_0.npz"),
                  "wb") as f:
            f.write(b"garbage")
        assert checkpoint.latest_step(d) == 5


def test_checkpoint_shape_mismatch_raises():
    tree = dict(x=jnp.ones((3,)))
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, tree)
        with pytest.raises(ValueError):
            checkpoint.restore(d, 1, dict(x=jnp.ones((4,))))


def test_token_pipeline_determinism_and_sharding():
    pipe = TokenPipeline(vocab=1000, seq_len=16, global_batch=8, seed=3)
    b1 = pipe.batch(5)
    b2 = pipe.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], pipe.batch(6)["tokens"])
    # host shards tile the global batch exactly
    h0 = pipe.host_batch(5, 0, 2)
    h1 = pipe.host_batch(5, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert b1["tokens"].max() < 1000


def test_psi_weighted_sampler_prefers_influencers():
    psi = np.asarray([0.001] * 99 + [0.9])
    s = PsiWeightedSampler(psi, seed=0)
    users = s.sample_users(5000)
    share = np.mean(users == 99)
    assert share > 0.5                      # influencer dominates
    flat = PsiWeightedSampler(np.ones(100), seed=0)
    assert flat.mixture_stats(2000)["top1_share"] < 0.05
