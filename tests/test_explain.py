"""Decision observability (PR 10): DecisionRecord/DecisionLog semantics,
plan_regime's audit trail (candidate table, density-gate prunes, cache
hit/miss, the ``source`` provenance field), the self-calibrating cost
model (median/MAD factors, skew → mis-rank → recovery), and the
EXPLAIN-ANALYZE renderers up through ``PsiService.explain()`` — plus the
bitwise-ψ parity contract with explain + calibration armed."""
import json
import os
import tempfile

import numpy as np
import pytest

from repro import obs
from repro.core import (Activity, PsiService, RATE_FLOOR, heterogeneous,
                        make_engine)
from repro.graphs import clustered_blocks, powerlaw_configuration
from repro.kernels import autotune
from repro.obs import calibrate as obs_calibrate
from repro.obs import explain as obs_explain
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs.explain import (Candidate, DecisionLog, DecisionRecord,
                               Pruned, decisions_for, explain_tree,
                               format_cost, render_decision)
from repro.obs.metrics import MetricsRegistry

# skewed (edge, bsr, node) bytes/slot: edge_tile looks ~free, BSR looks
# ruinous — the calibration acceptance drill injects these
SKEW = (0.001, 1e5, 16.0)


@pytest.fixture
def fresh_obs():
    """Isolated registry/tracker/decision-log per test."""
    prev = obs.configure(registry=MetricsRegistry(),
                         tracer=obs.Tracer(None),
                         tracker=obs.ConvergenceTracker(),
                         decisions=DecisionLog())
    obs_log.clear()
    yield obs_metrics.get_registry()
    obs.restore(prev)


@pytest.fixture(scope="module")
def sparse_graph():
    return powerlaw_configuration(1_000, 7_000, seed=17)


@pytest.fixture(scope="module")
def blocky_graph():
    return clustered_blocks(256, 12_000, block=128, p_in=1.0, seed=3)


def _fake_bench_bsr_wins(graph, plan, dtype, interpret):
    return 100.0 if plan.regime == "bsr" else 5_000.0


# --------------------------------------------------------------------- #
# DecisionLog / DecisionRecord semantics
# --------------------------------------------------------------------- #
def test_decision_log_ring_and_filters(fresh_obs):
    log = obs_explain.get_log()
    for i in range(5):
        obs_explain.record_decision("regime_plan", f"site{i}")
    obs_explain.record_decision("solver_choice", "s")
    assert len(log) == 6
    assert [r.site for r in log.recent(2, kind="regime_plan")] \
        == ["site3", "site4"]
    assert log.last(kind="solver_choice").kind == "solver_choice"
    assert log.last(kind="early_stop") is None


def test_decision_log_bounded():
    log = DecisionLog(keep=4)
    for i in range(10):
        log.record(DecisionRecord("regime_plan", f"s{i}"))
    assert len(log) == 4
    assert log.recent()[0].site == "s6"


def test_record_decision_counts_by_kind(fresh_obs):
    obs_explain.record_decision("regime_plan", "a")
    obs_explain.record_decision("regime_plan", "b")
    obs_explain.record_decision("early_stop", "c")
    assert fresh_obs.value("psi_plan_decisions_total",
                           kind="regime_plan") == 2
    assert fresh_obs.value("psi_plan_decisions_total",
                           kind="early_stop") == 1


def test_disable_nulls_the_decision_log(sparse_graph):
    prev = obs.disable()
    try:
        assert obs_explain.record_decision("regime_plan", "x") is None
        autotune.plan_regime(sparse_graph, cache=None, calibration=None)
        assert len(obs_explain.get_log()) == 0
    finally:
        obs.restore(prev)
    assert obs_explain.get_log().enabled


def test_decisions_for_prefers_matching_shape(fresh_obs):
    obs_explain.record_decision("regime_plan", "a", inputs=dict(n=10, m=20))
    obs_explain.record_decision("regime_plan", "b", inputs=dict(n=99, m=77))
    picked = decisions_for(n=10, m=20)
    assert [r.site for r in picked] == ["a"]
    # no match for the shape → newest of the kind still surfaces
    picked = decisions_for(n=1, m=2)
    assert [r.site for r in picked] == ["b"]


def test_decision_record_json_roundtrips():
    rec = DecisionRecord(
        "regime_plan", "plan_regime", inputs=dict(n=5, m=9),
        cache="miss", chosen="edge_tile(tile=256)", source="model",
        candidates=[Candidate("edge_tile(tile=256)", est=1024.0,
                              chosen=True)],
        pruned=[Pruned("bsr(ts=128,td=128)", "BSR_MIN_OCCUPANCY",
                       detail=dict(occupancy=0.001))])
    doc = json.loads(json.dumps(rec.to_json()))
    assert doc["kind"] == "regime_plan" and doc["cache"] == "miss"
    assert doc["candidates"][0]["chosen"] is True
    assert doc["pruned"][0]["reason"] == "BSR_MIN_OCCUPANCY"


# --------------------------------------------------------------------- #
# plan_regime's audit trail
# --------------------------------------------------------------------- #
def test_plan_regime_records_candidates_prunes_and_cache(fresh_obs,
                                                         sparse_graph):
    cache = autotune.PlanCache()
    plan = autotune.plan_regime(sparse_graph, cache=cache, calibration=None)
    rec = obs_explain.get_log().last(kind="regime_plan")
    assert rec.cache == "miss" and rec.chosen == plan.label()
    assert rec.source == "model" and plan.source == "model"
    assert sum(c.chosen for c in rec.candidates) == 1
    assert len(rec.candidates) >= 2          # alternatives kept, not just winner
    # hyper-sparse graph: every BSR parameterization is density-gated
    assert rec.pruned and all(p.reason == "BSR_MIN_OCCUPANCY"
                              for p in rec.pruned)
    assert all(p.detail["occupancy"] < autotune.BSR_MIN_OCCUPANCY
               for p in rec.pruned)

    autotune.plan_regime(sparse_graph, cache=cache, calibration=None)
    rec2 = obs_explain.get_log().last(kind="regime_plan")
    assert rec2.cache == "hit" and rec2.chosen == plan.label()
    assert fresh_obs.value("psi_plan_cache_hits_total") == 1
    assert fresh_obs.value("psi_plan_cache_misses_total") == 1


def test_plan_cache_size_gauge_tracks_global_cache_only(fresh_obs,
                                                        sparse_graph):
    before = len(autotune.PLAN_CACHE)
    autotune.plan_regime(sparse_graph, calibration=None)
    assert fresh_obs.value("psi_plan_cache_size") == before + 1
    # a private cache must not fight the process-level gauge
    autotune.plan_regime(sparse_graph, cache=autotune.PlanCache(),
                         calibration=None)
    assert fresh_obs.value("psi_plan_cache_size") == before + 1


def test_microbench_sets_source_and_feeds_store(fresh_obs, blocky_graph,
                                                monkeypatch):
    monkeypatch.setattr(autotune, "_microbench_step", _fake_bench_bsr_wins)
    store = obs_calibrate.CalibrationStore(env="test|cpu|False")
    plan = autotune.plan_regime(blocky_graph, cache=None, microbench=True,
                                calibration=store)
    assert plan.source == "microbench" and plan.regime == "bsr"
    rec = obs_explain.get_log().last(kind="regime_plan")
    assert rec.source == "microbench"
    assert all(c.measured_us > 0 for c in rec.candidates)
    # one observation per surviving candidate landed in the store
    assert len(store) == len(rec.candidates)
    assert set(store.factors()) == {"bsr", "edge_tile"}


# --------------------------------------------------------------------- #
# CalibrationStore math
# --------------------------------------------------------------------- #
def test_store_ratio_median_mad_and_confidence():
    store = obs_calibrate.CalibrationStore(env="e")
    assert store.observe("edge_tile", 0.0, 5.0) is None   # no information
    assert store.observe("edge_tile", 10.0, -1.0) is None
    assert store.factor("edge_tile") is None
    assert store.observe("edge_tile", 100.0, 200.0) == 2.0
    assert store.factor("edge_tile") is None              # below min_samples
    store.observe("edge_tile", 100.0, 400.0)
    f = store.factor("edge_tile")
    assert f == {"median": 3.0, "mad": 1.0, "count": 2}
    assert store.corrected_us("edge_tile", 10.0) == 30.0
    assert store.corrected_us("bsr", 10.0) is None


def test_store_multipliers_cannot_flip_unknown_regimes():
    store = obs_calibrate.CalibrationStore(env="e")
    assert store.multipliers({"edge_tile", "bsr"}) == {}
    store.observe("edge_tile", 1.0, 4.0)
    store.observe("edge_tile", 1.0, 4.0)
    mult = store.multipliers({"edge_tile", "bsr"})
    # the unknown regime inherits the confident median: uniform scaling,
    # identical relative ordering
    assert mult == {"edge_tile": 4.0, "bsr": 4.0}


def test_store_generation_bumps_only_on_material_drift():
    store = obs_calibrate.CalibrationStore(env="e")
    store.observe("bsr", 1.0, 2.0)
    assert store.generation == 0                  # not yet confident
    store.observe("bsr", 1.0, 2.0)
    assert store.generation == 1                  # first publication
    store.observe("bsr", 1.0, 2.01)               # median moves <10%
    assert store.generation == 1
    gen = store.generation
    for _ in range(8):
        store.observe("bsr", 1.0, 10.0)           # median drifts hard
    assert store.generation > gen                 # material drift republishes


def test_store_save_load_roundtrip(tmp_path):
    store = obs_calibrate.CalibrationStore(env="e")
    store.observe("bsr", 2.0, 6.0)
    store.observe("bsr", 2.0, 10.0)
    path = os.path.join(tmp_path, "CALIB_power_psi.json")
    snap = store.save(path)
    assert snap["entries"][0]["regime"] == "bsr"
    fresh = obs_calibrate.CalibrationStore(env="e")
    assert fresh.load(path) == 1
    assert fresh.factor("bsr") == store.factor("bsr")
    assert fresh.load(os.path.join(tmp_path, "missing.json")) == 0


def test_store_is_per_environment():
    store = obs_calibrate.CalibrationStore(env="cpu|cpu|False")
    store.observe("bsr", 1.0, 3.0, env="tpu|v5e|True")
    store.observe("bsr", 1.0, 3.0, env="tpu|v5e|True")
    assert store.factor("bsr") is None            # wrong machine class
    assert store.factor("bsr", env="tpu|v5e|True")["median"] == 3.0


# --------------------------------------------------------------------- #
# the acceptance drill: skew → mis-rank → calibrate → recover
# --------------------------------------------------------------------- #
def test_skewed_model_misranks_then_calibration_recovers(fresh_obs,
                                                         blocky_graph,
                                                         monkeypatch):
    monkeypatch.setattr(autotune, "_microbench_step", _fake_bench_bsr_wins)
    uncal = autotune.plan_regime(blocky_graph, cache=None, calibration=None,
                                 slot_bytes=SKEW)
    assert uncal.regime == "edge_tile"            # the skew mis-ranks

    store = obs_calibrate.CalibrationStore(env="test|cpu|False")
    bench = autotune.plan_regime(blocky_graph, cache=None, microbench=True,
                                 calibration=store, slot_bytes=SKEW)
    assert bench.regime == "bsr"                  # measured ground truth
    events = obs_log.recent(name="model_misranked")
    assert events and events[-1]["basis"] == "microbench"

    recovered = autotune.plan_regime(blocky_graph, cache=None,
                                     calibration=store, slot_bytes=SKEW)
    assert recovered.regime == "bsr"
    assert recovered.source == "calibrated"
    rec = obs_explain.get_log().last(kind="regime_plan")
    assert rec.source == "calibrated"
    assert rec.calibration and rec.calibration["factors"]
    chosen = next(c for c in rec.candidates if c.chosen)
    assert chosen.calibrated_us is not None
    assert fresh_obs.value("psi_plan_misprediction_ratio") > 1.0
    assert obs_log.recent(name="model_misranked")[-1]["basis"] \
        == "calibration"


def test_calibration_generation_invalidates_plan_cache(fresh_obs,
                                                       blocky_graph,
                                                       monkeypatch):
    monkeypatch.setattr(autotune, "_microbench_step", _fake_bench_bsr_wins)
    store = obs_calibrate.CalibrationStore(env="test|cpu|False")
    cache = autotune.PlanCache()
    p1 = autotune.plan_regime(blocky_graph, cache=cache, calibration=store,
                              slot_bytes=SKEW)
    p1b = autotune.plan_regime(blocky_graph, cache=cache, calibration=store,
                               slot_bytes=SKEW)
    assert p1b == p1 and len(cache) == 1
    # material recalibration bumps the generation → the stale memoized
    # plan is not served again
    autotune.plan_regime(blocky_graph, cache=None, microbench=True,
                         calibration=store, slot_bytes=SKEW)
    assert store.generation >= 1
    p2 = autotune.plan_regime(blocky_graph, cache=cache, calibration=store,
                              slot_bytes=SKEW)
    assert p2.regime == "bsr" and p2.source == "calibrated"
    assert len(cache) == 2


# --------------------------------------------------------------------- #
# renderers
# --------------------------------------------------------------------- #
def test_format_cost_units():
    assert format_cost(None, "bytes") == "-"
    assert format_cost(512.0, "bytes") == "512B"
    assert format_cost(200 * 1024.0, "bytes") == "200.00KB"
    assert format_cost(3 << 20, "bytes") == "3.00MB"
    assert format_cost(250.0, "us") == "250.0µs"
    assert format_cost(12_500.0, "us") == "12.50ms"
    assert format_cost(5.8e5, "edges") == "5.8e+05 edges"


def test_render_decision_marks_winner_and_regret():
    rec = DecisionRecord(
        "regime_plan", "plan_regime", inputs=dict(n=10, m=20),
        cache="miss", chosen="a", source="model",
        candidates=[Candidate("b", est=150.0), Candidate("a", est=100.0,
                                                         chosen=True)])
    lines = render_decision(rec)
    assert lines[0].startswith(
        "regime_plan via plan_regime [PLAN_CACHE miss] source=model")
    assert lines[1].lstrip().startswith("chosen  a")   # winner sorts first
    assert "(+50%)" in lines[2]                        # regret vs winner


def test_explain_tree_renders_empty_and_full(fresh_obs):
    empty = explain_tree(header="H")
    assert empty.splitlines()[0] == "H"
    assert "no recorded decisions" in empty
    rec = obs_explain.record_decision(
        "solver_choice", "choose_solver", inputs=dict(n=4),
        chosen="push", candidates=[Candidate("push", est=1.0, unit="edges",
                                             chosen=True)])
    out = explain_tree(header="H", decisions=[rec],
                       query=dict(op="scores", cache="hit", stale=False,
                                  seconds=1e-3),
                       extra=dict(k="v"))
    assert "├─ solver_choice via choose_solver" in out
    assert "query op=scores cache=hit stale=False wall=1.00ms" in out
    assert out.splitlines()[-1] == "└─ k=v"


# --------------------------------------------------------------------- #
# service-level explain + parity
# --------------------------------------------------------------------- #
def _small_service(backend="reference"):
    import jax.numpy as jnp
    g = powerlaw_configuration(300, 1_800, seed=5)
    act = heterogeneous(g.n, seed=6)
    return g, PsiService(g, act, tol=1e-8, backend=backend,
                         dtype=jnp.float64)


def test_service_explain_renders_resolve_and_solver_choice(fresh_obs):
    g, svc = _small_service()
    svc.update_activity(np.asarray([1]), lam=np.asarray([3.0]))
    svc.top_k(3)
    tree = svc.explain()
    assert tree.splitlines()[0].startswith(
        "EXPLAIN ANALYZE — power-ψ [backend=reference]")
    assert "resolve #" in tree and "solver_choice via choose_solver" in tree
    assert "query op=" in tree
    # the solver decision carries the measured dirty fraction
    rec = obs_explain.get_log().last(kind="solver_choice")
    assert 0.0 < rec.inputs["dirty_frac"] <= 1.0
    assert rec.inputs["n"] == g.n


def test_push_backend_records_early_stop_decision(fresh_obs):
    g = powerlaw_configuration(400, 2_400, seed=11)
    act = heterogeneous(g.n, seed=12)
    eng = make_engine("push", graph=g, activity=act)
    res, cert = eng.run_top_k(5, tol=1e-10)
    rec = obs_explain.get_log().last(kind="early_stop")
    assert rec is not None and rec.site == "PushEngine.run_top_k"
    assert rec.inputs["k"] == 5
    want = "certified_early_stop" if cert.certified else "exhausted_to_tol"
    assert rec.chosen == want
    assert {c.name for c in rec.candidates} \
        == {"certified_early_stop", "exhausted_to_tol"}


def test_fleet_records_bucket_regime_rule(fresh_obs):
    from repro.serving import BucketPolicy, TenantFleet
    fleet = TenantFleet(backend="reference", tol=1e-8,
                        policy=BucketPolicy((64,), edge_quantum=256))
    g = powerlaw_configuration(48, 200, seed=2)
    fleet.admit("a", g, heterogeneous(g.n, seed=3))
    fleet.solve()
    rec = obs_explain.get_log().last(kind="bucket_regime")
    assert rec is not None and rec.chosen == "reference"
    assert "pinned" in next(c for c in rec.candidates if c.chosen) \
        .detail["rule"]


def test_bitwise_parity_with_explain_and_calibration_armed(fresh_obs):
    _, svc = _small_service()
    svc.update_activity(np.asarray([0]), lam=np.asarray([2.0]))
    psi_live = np.array(svc.scores(), copy=True)
    assert len(obs_explain.get_log()) > 0         # explain really armed

    # populated calibration store stays armed across obs.disable(): it is
    # planner input, not telemetry
    store = obs_calibrate.CalibrationStore(env="test|cpu|False")
    store.observe("edge_tile", 1.0, 7.0)
    store.observe("edge_tile", 1.0, 7.0)
    prev_store = obs_calibrate.set_store(store)
    prev = obs.disable()
    try:
        _, svc2 = _small_service()
        svc2.update_activity(np.asarray([0]), lam=np.asarray([2.0]))
        psi_null = np.array(svc2.scores(), copy=True)
        assert len(obs_explain.get_log()) == 0
    finally:
        obs.restore(prev)
        obs_calibrate.set_store(prev_store)
    assert np.array_equal(psi_live, psi_null)


def test_auto_engine_feeds_step_span_calibration(fresh_obs):
    import jax.numpy as jnp
    g = powerlaw_configuration(400, 2_400, seed=9)
    act = heterogeneous(g.n, seed=10)
    store = obs_calibrate.CalibrationStore(env="test|cpu|False",
                                           min_samples=1)
    prev_store = obs_calibrate.set_store(store)
    try:
        eng = make_engine("auto", graph=g, activity=act, dtype=jnp.float64)
        res = eng.run(tol=1e-10)
        assert res.converged
        assert len(store) == 1                    # one wall/iter sample
        (key,) = store._samples
        assert key[1] == eng.plan.regime
    finally:
        obs_calibrate.set_store(prev_store)


def test_obs_dump_carries_decisions_and_calibration(fresh_obs, tmp_path):
    obs_explain.record_decision("regime_plan", "x", chosen="a")
    snap = obs.dump(os.path.join(tmp_path, "dump.json"))
    assert snap["decisions"][-1]["site"] == "x"
    assert "calibration" in snap
