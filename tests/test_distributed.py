"""Multi-device tests (spawned subprocesses — the 512-device forcing must
never leak into the main pytest process, which sees 1 device)."""
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_distributed_psi_matches_serial():
    print(_run("""
import numpy as np, jax
from repro.graphs import erdos_renyi
from repro.core import heterogeneous, build_operators, power_psi
from repro.core.distributed import DistributedPsi
g = erdos_renyi(600, 4500, seed=4)
act = heterogeneous(g.n, seed=9)
ref = power_psi(build_operators(g, act), tol=1e-10)
for shape, axes in [((2, 4), ("data", "model")),
                    ((2, 2, 2), ("pod", "data", "model"))]:
    mesh = jax.make_mesh(shape, axes)
    dp = DistributedPsi.from_graph(g, act, mesh)
    psi, iters, gap = dp.run_to_convergence(tol=1e-7, chunk_iters=8)
    err = np.abs(psi - np.asarray(ref.psi)).max()
    assert err < 1e-6, (shape, err)
print("ok")
"""))


def test_driver_restart_and_straggler_flags():
    print(_run("""
import numpy as np, jax, tempfile
from repro.graphs import erdos_renyi
from repro.core import heterogeneous, build_operators, power_psi
from repro.core.distributed import DistributedPsi
from repro.runtime import PsiDriver
g = erdos_renyi(500, 3500, seed=5)
act = heterogeneous(g.n, seed=6)
ref = power_psi(build_operators(g, act), tol=1e-10)
mesh = jax.make_mesh((2, 4), ("data", "model"))
dist = DistributedPsi.from_graph(g, act, mesh)
with tempfile.TemporaryDirectory() as d:
    drv = PsiDriver(dist, ckpt_dir=d, chunk_iters=8)
    rep = drv.run(tol=1e-7, fail_hook=lambda c: c in (1, 3))
    assert rep.restarts == 2
    assert np.abs(rep.psi - np.asarray(ref.psi)).max() < 1e-6
print("ok")
"""))


def test_elastic_remesh_preserves_fixed_point():
    print(_run("""
import numpy as np, jax
from repro.graphs import erdos_renyi
from repro.core import heterogeneous, build_operators, power_psi
from repro.core.distributed import DistributedPsi
from repro.runtime import PsiDriver
g = erdos_renyi(640, 5000, seed=7)
act = heterogeneous(g.n, seed=8)
ref = power_psi(build_operators(g, act), tol=1e-10)
mesh1 = jax.make_mesh((2, 4), ("data", "model"))
dist1 = DistributedPsi.from_graph(g, act, mesh1)
run1 = dist1.make_run(chunk_iters=8)
s1, _ = run1(dist1.arrays.c_src, dist1.arrays)
drv2 = PsiDriver(dist1, chunk_iters=8).remesh(
    jax.make_mesh((4, 2), ("data", "model")), g, act, s1)
dist2 = drv2.dist
run2 = dist2.make_run(chunk_iters=8)
s, gap = drv2._warm_s, np.inf
it = 8
while gap > 1e-7 and it < 400:
    s, gdev = run2(s, dist2.arrays); gap = float(gdev); it += 8
epi = jax.jit(dist2.make_epilogue())
psi = dist2.part.from_src_layout(
    np.asarray(epi(s, dist2.arrays)).reshape(dist2.part.d, -1))
assert np.abs(psi - np.asarray(ref.psi)).max() < 1e-6
print("ok, resumed at iter", it)
"""))


def test_remeshed_driver_consumes_warm_start():
    """Regression: remesh() hands the driver a warm vector and run() must
    actually consume it — the re-meshed run converges in fewer iterations
    than a cold driver on the same mesh."""
    print(_run("""
import numpy as np, jax
from repro.graphs import erdos_renyi
from repro.core import heterogeneous
from repro.core.distributed import DistributedPsi
from repro.runtime import PsiDriver
g = erdos_renyi(640, 5000, seed=7)
act = heterogeneous(g.n, seed=8)
mesh1 = jax.make_mesh((2, 4), ("data", "model"))
dist1 = DistributedPsi.from_graph(g, act, mesh1)
# progress the contraction a few chunks on the old mesh
run1 = dist1.make_run(chunk_iters=8)
s1 = dist1.arrays.c_src
for _ in range(3):
    s1, _ = run1(s1, dist1.arrays)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
warm_drv = PsiDriver(dist1, chunk_iters=8).remesh(mesh2, g, act, s1)
warm = warm_drv.run(tol=1e-7)
cold = PsiDriver(warm_drv.dist, chunk_iters=8).run(tol=1e-7)
assert warm.iterations < cold.iterations, (warm.iterations, cold.iterations)
assert np.abs(warm.psi - cold.psi).max() < 1e-6
print("ok: warm", warm.iterations, "< cold", cold.iterations)
"""))


def test_dispatch_finalize_halves_match_fused_step():
    """The PartialReduction split (compute half / collective half) is
    iteration-equivalent to the fused step on a real 2×4 mesh, and driving
    the whole contraction through the halves reaches the serial ψ."""
    print(_run("""
import numpy as np, jax
from repro.graphs import erdos_renyi
from repro.core import heterogeneous, build_operators, power_psi
from repro.core.distributed import DistributedPsi
g = erdos_renyi(600, 4500, seed=4)
act = heterogeneous(g.n, seed=9)
ref = power_psi(build_operators(g, act), tol=1e-10)
mesh = jax.make_mesh((2, 4), ("data", "model"))
dp = DistributedPsi.from_graph(g, act, mesh)
step = jax.jit(dp.make_step())
dispatch = jax.jit(dp.make_dispatch())
finalize = jax.jit(dp.make_finalize())
s = dp.arrays.c_src
gap = np.inf
for it in range(200):
    s_fused, gap_fused = step(s, dp.arrays)
    s, gap = finalize(dispatch(s, dp.arrays), dp.arrays)
    assert np.allclose(np.asarray(s), np.asarray(s_fused), rtol=1e-6), it
    assert abs(float(gap) - float(gap_fused)) <= 1e-6 * max(float(gap), 1e-30)
    if float(gap) <= 1e-7:
        break
epi = jax.jit(dp.make_epilogue())
psi = dp.part.from_src_layout(
    np.asarray(epi(s, dp.arrays)).reshape(dp.part.d, -1))
assert np.abs(psi - np.asarray(ref.psi)).max() < 1e-6
print("ok at iter", it)
"""))


def test_sharded_embedding_lookup_and_grads():
    print(_run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.recsys.embedding import sharded_lookup
mesh = jax.make_mesh((2, 4), ("data", "model"))
tbl = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8))
                  .astype(np.float32))
tbl_s = jax.device_put(tbl, NamedSharding(mesh, P("model", None)))
ids = jnp.asarray(np.random.default_rng(1).integers(0, 64, (8, 3)))
out = sharded_lookup(tbl_s, ids, mesh, batch_axes=("data",))
assert float(jnp.abs(out - jnp.take(tbl, ids, axis=0)).max()) == 0.0
g = jax.grad(lambda t: jnp.sum(
    sharded_lookup(t, ids, mesh, batch_axes=("data",)) ** 2))(tbl_s)
gr = jax.grad(lambda t: jnp.sum(jnp.take(t, ids, axis=0) ** 2))(tbl)
assert float(jnp.abs(g - gr).max()) == 0.0
print("ok")
"""))


def test_lm_sharded_step_runs():
    """Reduced tinyllama train step on a real 2×4 mesh with its full
    sharding pipeline (FSDP+TP constraints, MoE shard_map)."""
    print(_run("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.models.transformer import init_params, make_train_step, param_specs
from repro.train import adamw, constant_schedule
import dataclasses
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ("tinyllama-1.1b", "mixtral-8x7b"):
    cfg = get_arch(arch).config(reduced=True)
    # reduced dims divisible by the 4-way model axis already (multiples of 8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    from jax.sharding import NamedSharding
    specs = param_specs(cfg, mesh)
    params = jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(mesh, s)),
        params, specs, is_leaf=lambda x: hasattr(x, "shape"))
    opt = adamw(constant_schedule(1e-3))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, mesh, opt))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 17)))
    batch = dict(tokens=toks[:, :-1], labels=toks[:, 1:])
    for _ in range(2):
        params, state, loss = step(params, state, batch)
    assert np.isfinite(float(loss)), arch
print("ok")
"""))


def test_1d_baseline_matches_serial():
    """Paper-faithful 1-D distribution (replicated s, full psum) — the
    §Perf comparison baseline for the 2-D block-cyclic schedule."""
    print(_run("""
import numpy as np, jax, jax.numpy as jnp
from repro.graphs import erdos_renyi
from repro.core import heterogeneous, build_operators, power_psi
from repro.core.distributed import DistributedPsi1D
g = erdos_renyi(500, 3600, seed=12)
act = heterogeneous(g.n, seed=13)
mesh = jax.make_mesh((8,), ("all",))
d1 = DistributedPsi1D(g, act, mesh)
step = jax.jit(d1.make_step())
a = d1.arrays
s = a["c"]
for _ in range(80):
    s = step(s, a["src"], a["dst"], a["inv_w"], a["mu"], a["c"])
    jax.block_until_ready(s)   # serialize (CPU communicator quirk)
ops = build_operators(g, act)
ref = power_psi(ops, tol=1e-10)
psi = np.asarray(ops.psi_epilogue(jnp.asarray(np.asarray(s)[:g.n])))
assert np.abs(psi - np.asarray(ref.psi)).max() < 1e-6
print("ok")
"""))


def test_sharded_2d_sage_matches_serial():
    """§Perf cell-3 optimization: 2-D block-cyclic message passing."""
    print(_run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.graphs import erdos_renyi
from repro.models.gnn import sage
from repro.models.gnn.common import batch_from_graph
from repro.models.gnn.sharded_mp import build_sharded_graph, sharded_sage_apply
g = erdos_renyi(600, 4200, seed=2)
cfg = sage.SageConfig(d_feat=16, n_classes=5, d_hidden=32, n_layers=2)
rng = np.random.default_rng(0)
x = rng.normal(size=(g.n, 16)).astype(np.float32)
params = sage.init_params(cfg, jax.random.PRNGKey(0))
ref = np.asarray(sage.apply(
    params, batch_from_graph(g, x, labels=rng.integers(0, 5, g.n)), cfg))
mesh = jax.make_mesh((2, 4), ("data", "model"))
part, sg = build_sharded_graph(g, mesh, bidirectional=True)
x_shard = jax.device_put(
    np.stack([part.to_src_layout(x[:, j]) for j in range(16)], -1),
    NamedSharding(mesh, P(("data",), None, None)))
out = sharded_sage_apply(params, x_shard, part, sg, mesh, cfg)
out_nodes = np.stack([part.from_src_layout(np.asarray(out)[..., j])
                      for j in range(out.shape[-1])], -1)
assert np.abs(out_nodes - ref).max() < 1e-5
print("ok")
"""))
