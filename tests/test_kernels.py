"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import erdos_renyi, powerlaw_configuration
from repro.core import heterogeneous, build_operators, power_psi
from repro.kernels import (build_edge_tiles, build_bsr, DeviceEdgeTiles,
                           DeviceBsr, edge_spmv, bsr_spmv, seg_mm,
                           power_step, PsiKernelEngine)
from repro.kernels.ref import edge_spmv_ref, power_step_ref, seg_mm_ref

GRAPHS = [
    ("er-small", lambda: erdos_renyi(100, 500, seed=1)),
    ("er-dense", lambda: erdos_renyi(256, 8000, seed=2)),
    ("powerlaw", lambda: powerlaw_configuration(700, 4200, seed=3)),
    ("tiny", lambda: erdos_renyi(40, 80, seed=4)),
]
TILES = [(128, 8, 128), (256, 8, 128), (512, 16, 128)]


@pytest.mark.parametrize("gname,gfn", GRAPHS)
@pytest.mark.parametrize("tile,e1,e2", TILES[:2])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_edge_spmv_matches_ref(gname, gfn, tile, e1, e2, dtype):
    g = gfn()
    fmt = DeviceEdgeTiles.from_format(build_edge_tiles(g, tile=tile, e1=e1,
                                                       e2=e2))
    s = jnp.asarray(
        np.random.default_rng(0).uniform(size=g.n).astype("float32"), dtype)
    out = edge_spmv(s, fmt)
    src, dst = g.edges_by_dst
    ref = edge_spmv_ref(s, jnp.asarray(src), jnp.asarray(dst), g.n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("gname,gfn", GRAPHS[:3])
@pytest.mark.parametrize("ts,td", [(128, 128), (128, 256)])
def test_bsr_spmv_matches_ref(gname, gfn, ts, td):
    g = gfn()
    fmt = DeviceBsr.from_format(build_bsr(g, ts=ts, td=td))
    s = jnp.asarray(
        np.random.default_rng(1).uniform(size=g.n).astype("float32"))
    out = bsr_spmv(s, fmt)
    src, dst = g.edges_by_dst
    ref = edge_spmv_ref(s, jnp.asarray(src), jnp.asarray(dst), g.n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_edge_spmv_weighted():
    g = erdos_renyi(150, 900, seed=7)
    fmt_h = build_edge_tiles(g, tile=128, e1=8, e2=128)
    fmt = DeviceEdgeTiles.from_format(fmt_h)
    rng = np.random.default_rng(2)
    s = jnp.asarray(rng.uniform(size=g.n).astype("float32"))
    # per-edge weights arranged in the padded block layout
    w_edge = rng.uniform(size=g.m).astype("float32")
    src, dst = g.edges_by_dst
    wpad = np.zeros(fmt_h.src_idx.size, "float32")
    slot = fmt_h.src_idx.reshape(-1) != g.n
    wpad[slot] = w_edge
    w = jnp.asarray(wpad.reshape(fmt_h.src_idx.shape))
    out = edge_spmv(s, fmt, weights=w)
    ref = edge_spmv_ref(s, jnp.asarray(src), jnp.asarray(dst), g.n,
                        weights=jnp.asarray(w_edge))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("d", [8, 16, 64])
def test_seg_mm_matches_ref(d):
    g = powerlaw_configuration(300, 1800, seed=5)
    fmt = DeviceEdgeTiles.from_format(build_edge_tiles(g, tile=128))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(g.n, d)).astype("float32"))
    xpad = jnp.concatenate([x, jnp.zeros((fmt.n_gather - g.n, d))], 0)
    eblk = fmt.e1 * fmt.e2
    msgs = xpad[fmt.src_idx.reshape(-1, eblk)]
    out = seg_mm(msgs, fmt)
    src, dst = g.edges_by_dst
    ref = seg_mm_ref(x[jnp.asarray(src)], jnp.asarray(dst), g.n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_power_step_fused_matches_ref():
    g = powerlaw_configuration(500, 3000, seed=6)
    act = heterogeneous(g.n, seed=7)
    ops = build_operators(g, act)
    fmt = DeviceEdgeTiles.from_format(build_edge_tiles(g, tile=256))
    s = ops.c
    s_pad = fmt.pad_node_vector(s)
    inv_w_g = fmt.pad_gather_source(ops.inv_w)
    mu_pad = fmt.pad_node_vector(ops.mu)
    c_pad = fmt.pad_node_vector(ops.c)
    s_new, gap = power_step(s_pad, inv_w_g, mu_pad, c_pad, fmt)
    src, dst = g.edges_by_dst
    ref_s, ref_gap = power_step_ref(s, ops.inv_w, ops.mu, ops.c,
                                    jnp.asarray(src), jnp.asarray(dst), g.n)
    np.testing.assert_allclose(np.asarray(s_new[0, :g.n]), np.asarray(ref_s),
                               rtol=2e-5, atol=2e-6)
    assert abs(float(gap) - float(ref_gap)) < 1e-3 * max(1.0, float(ref_gap))


def test_kernel_engine_full_psi():
    """Alg. 2 driven end-to-end by the fused Pallas step == reference."""
    g = erdos_renyi(400, 2400, seed=8)
    act = heterogeneous(g.n, seed=9)
    eng = PsiKernelEngine(g, act, tile=128)
    res_k = eng.run(tol=1e-8)
    res_r = power_psi(build_operators(g, act), tol=1e-8)
    np.testing.assert_allclose(np.asarray(res_k.psi), np.asarray(res_r.psi),
                               rtol=1e-4, atol=1e-8)


def test_bsr_occupancy_reported():
    """Hyper-sparse graphs give low BSR occupancy — the §Perf ablation."""
    g = powerlaw_configuration(2000, 12000, seed=11)
    fmt = build_bsr(g, ts=128, td=128)
    assert 0.0 < fmt.occupancy < 0.2
