import os
import sys

# tests see the default (1) device count — the 512-device forcing belongs to
# launch/dryrun.py ONLY. Distributed tests spawn subprocesses instead.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
