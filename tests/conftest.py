import os
import sys

import pytest

# tests see the default (1) device count — the 512-device forcing belongs to
# launch/dryrun.py ONLY. Distributed tests spawn subprocesses instead.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _fresh_calibration_store():
    """Isolate the process-global cost-model calibration store per test.

    The store is planner *input* (it survives ``obs.disable()`` by
    design), so samples fed by one test — a microbenched plan, an auto
    engine's step-span timings — would otherwise leak into every later
    test's plan ranking and cache keys."""
    from repro.obs import calibrate
    prev = calibrate.set_store(calibrate.CalibrationStore())
    yield
    calibrate.set_store(prev)
