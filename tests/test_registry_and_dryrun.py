"""Registry completeness (the 10-arch assignment) + dry-run parser units."""
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, list_archs
from repro.launch.dryrun import parse_collectives, _shape_bytes


def test_all_assigned_archs_present():
    want = {"tinyllama-1.1b", "yi-9b", "nemotron-4-340b", "mixtral-8x22b",
            "mixtral-8x7b", "pna", "equiformer-v2", "nequip",
            "graphsage-reddit", "mind", "psi-score"}
    assert want == set(list_archs())


def test_arch_shape_cell_count():
    """10 assigned archs × 4 shapes = 40 cells (+ψ's own)."""
    cells = [(a, s.name) for a in ARCHS.values() if a.family != "psi"
             for s in a.shapes]
    assert len(cells) == 40
    skips = [(a.arch_id, s.name) for a in ARCHS.values()
             for s in a.shapes if s.skip]
    # exactly the three pure-full-attention long_500k cells are skipped
    assert sorted(skips) == [("nemotron-4-340b", "long_500k"),
                             ("tinyllama-1.1b", "long_500k"),
                             ("yi-9b", "long_500k")]


def test_exact_assigned_configs():
    """Config values must match the assignment table verbatim."""
    c = get_arch("tinyllama-1.1b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (22, 2048, 32, 4, 5632, 32000)
    c = get_arch("yi-9b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 4096, 32, 4, 11008, 64000)
    c = get_arch("nemotron-4-340b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.act) == (96, 18432, 96, 8, 73728, 256000, "sq_relu")
    c = get_arch("mixtral-8x22b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.moe.n_experts, c.moe.top_k) == (56, 6144, 48, 8, 16384, 32768,
                                              8, 2)
    c = get_arch("mixtral-8x7b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.moe.n_experts, c.moe.top_k) == (32, 4096, 32, 8, 14336, 32000,
                                              8, 2)
    c = get_arch("pna").config()
    assert (c.n_layers, c.d_hidden) == (4, 75)
    c = get_arch("equiformer-v2").config()
    assert (c.n_layers, c.d_hidden, c.l_max, c.m_max, c.n_heads) == \
        (12, 128, 6, 2, 8)
    c = get_arch("nequip").config()
    assert (c.n_layers, c.d_hidden, c.l_max, c.n_rbf, c.cutoff) == \
        (5, 32, 2, 8, 5.0)
    c = get_arch("graphsage-reddit").config()
    assert (c.n_layers, c.d_hidden, c.aggregator, c.sample_sizes) == \
        (2, 128, "mean", (25, 10))
    c = get_arch("mind").config()
    assert (c.embed_dim, c.n_interests, c.capsule_iters) == (64, 4, 3)


def test_shape_bytes_parser():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("(bf16[8,4]{1,0}, s32[16])") == 8 * 4 * 2 + 16 * 4
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_scopes():
    hlo = """
HloModule mod
%wbody.1 (p: f32[8]) -> f32[8] {
  %x = f32[8]{0} all-reduce(f32[8]{0} %p), replica_groups={}
  ROOT %r = f32[8]{0} add(%x, %x)
}
%wcond.2 (p: f32[8]) -> pred[] {
  ROOT %t = pred[] constant(true)
}
ENTRY %main (a: f32[16]) -> f32[16] {
  %g = f32[16]{0} all-gather(f32[8]{0} %a), dimensions={0}
  %w = f32[8]{0} while(f32[8]{0} %g), condition=%wcond.2, body=%wbody.1
  ROOT %out = f32[16]{0} all-gather(f32[8]{0} %w), dimensions={0}
}
"""
    out = parse_collectives(hlo)
    assert out["all-reduce"]["in_while"] == 32
    assert out["all-reduce"]["top"] == 0
    assert out["all-gather"]["top"] == 128
    assert out["all-gather"]["count"] == 2


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_configs_instantiate(arch):
    cfg = get_arch(arch).config(reduced=True)
    assert cfg.name.endswith("-reduced") or "reduced" in cfg.name
