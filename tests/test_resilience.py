"""Chaos suite: fault injection, numerical sentinels, quarantine, the
supervised-resolve ladder, and crash-consistent exactly-once recovery
(docs/RESILIENCE.md). The f64 acceptance gate (ψ parity ≤ 1e-12) runs in a
spawned x64 subprocess, mirroring the CI smoke step."""
import glob
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.core import HostOperators, PsiService, heterogeneous, make_engine
from repro.graphs import erdos_renyi, powerlaw_configuration
from repro.asyncexec import AsyncPsiDriver
from repro.resilience import (ExactlyOnceReplay, FaultPlan, LaneQuarantine,
                              ResilientResolver, Sentinels, ServiceGuard,
                              alpha_norm, psi_residual_bound)
from repro.resilience.check import run_chaos
from repro.serving import BucketPolicy, TenantFleet
from repro.stream.estimator import RateEstimator
from repro.stream.events import poisson_stream

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tree(n=5, salt=0.0):
    return dict(a=np.arange(n) + salt, b=np.full(3, salt))


def _truncate(path: str, frac: float = 0.5) -> None:
    with open(path) as f:
        text = f.read()
    with open(path, "w") as f:
        f.write(text[: max(1, int(len(text) * frac))])


# --------------------------------------------------------------------- #
# S1/S3: checkpoint hardening — torn manifests, missing shards, GC races
# --------------------------------------------------------------------- #
def test_truncated_manifest_falls_back_to_previous_step():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            checkpoint.save(d, s, _tree(salt=float(s)))
        _truncate(os.path.join(d, "step_00000003", "MANIFEST.json"))
        with pytest.warns(RuntimeWarning):
            assert checkpoint.latest_step(d) == 2
        with pytest.warns(RuntimeWarning):
            data = checkpoint.restore_latest(d, _tree())
        assert data is not None and data["a"][0] == 2.0
        # explicit-step restore of a step that isn't there must raise
        with pytest.raises((ValueError, OSError, KeyError)):
            checkpoint.restore(d, 99, _tree())


def test_missing_shard_falls_back():
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, _tree(salt=1.0))
        checkpoint.save(d, 2, _tree(salt=2.0))
        shard = glob.glob(os.path.join(d, "step_00000002", "host_*.npz"))[0]
        os.remove(shard)
        with pytest.warns(RuntimeWarning):
            data = checkpoint.restore_latest(d, _tree())
        assert data["a"][0] == 1.0
        assert checkpoint.complete_steps(d) == [1]
        # explicit-step restore of the gutted step must raise, not guess
        with pytest.raises((ValueError, OSError, KeyError)):
            checkpoint.restore(d, 2, _tree())


def test_gc_race_mid_restore_is_survived():
    # a concurrent save(keep=...) can prune a step after all_steps() listed
    # it; the walker must skip the vanished/corrupted step, not crash
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            checkpoint.save(d, s, _tree(salt=float(s)))
        step3 = os.path.join(d, "step_00000003")
        for f in glob.glob(os.path.join(step3, "host_*.npz")):
            os.remove(f)                     # manifest still lists them
        with pytest.warns(RuntimeWarning):
            data = checkpoint.restore_latest(d, _tree())
        assert data["a"][0] == 2.0
        # GC itself keeps only complete newest steps reachable
        checkpoint.save(d, 4, _tree(salt=4.0), keep=2)
        assert 1 not in checkpoint.all_steps(d)


def test_every_checkpoint_torn_returns_none():
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, _tree())
        _truncate(os.path.join(d, "step_00000001", "MANIFEST.json"))
        with pytest.warns(RuntimeWarning):
            assert checkpoint.restore_latest(d, _tree()) is None


# --------------------------------------------------------------------- #
# S2: rate validation at every mutation boundary
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_platform():
    g = erdos_renyi(120, 700, seed=7)
    act = heterogeneous(g.n, seed=8)
    return g, act


@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf, -0.5])
def test_host_operators_reject_bad_rates(small_platform, bad):
    g, act = small_platform
    host = HostOperators.from_graph(g, act)
    lam0, mu0 = host.lam.copy(), host.mu.copy()
    with pytest.raises(ValueError):
        host.patch_activity(np.asarray([3]), lam=np.asarray([bad]))
    with pytest.raises(ValueError):
        host.patch_activity(np.asarray([3]), mu=np.asarray([bad]))
    assert np.array_equal(host.lam, lam0) and np.array_equal(host.mu, mu0)


def test_psi_service_rejects_bad_rates(small_platform):
    g, act = small_platform
    svc = PsiService(g, act, tol=1e-8)
    before = svc.scores().copy()
    with pytest.raises(ValueError):
        svc.update_activity(np.asarray([1]), lam=np.asarray([np.nan]))
    with pytest.raises(ValueError):
        svc.update_activity(np.asarray([1]), mu=np.asarray([-2.0]))
    assert np.array_equal(svc.scores(), before)


def test_estimator_rejects_non_finite_timestamp():
    est = RateEstimator(10)
    est.observe_post(1.0, 3)
    state = est.state_dict()
    with pytest.raises(ValueError):
        est.observe_post(float("nan"), 3)
    with pytest.raises(ValueError):
        est.observe_repost(float("inf"), 4)
    after = est.state_dict()
    assert all(np.array_equal(state[k], after[k]) for k in state)


def test_estimator_state_roundtrip():
    est = RateEstimator(12, half_life=8.0)
    for t in range(1, 30):
        est.observe_post(float(t), t % 12)
        est.observe_repost(float(t) + 0.5, (t * 5) % 12)
    est.drain(20.0)
    clone = RateEstimator(12, half_life=8.0)
    clone.load_state(est.state_dict())
    a, b = est.activity(30.0), clone.activity(30.0)
    assert np.array_equal(a.lam, b.lam) and np.array_equal(a.mu, b.mu)


# --------------------------------------------------------------------- #
# Fault harness: determinism + exactly-once transport repair
# --------------------------------------------------------------------- #
def test_faulty_feed_is_deterministic_and_repairable(small_platform):
    g, act = small_platform
    log = poisson_stream(act, 3.0, seed=11, graph=g)
    plan = FaultPlan(seed=3, dup_every=7, drop_every=11, reorder_window=4)

    runs = []
    for _ in range(2):
        clock = plan.clock()
        feed = clock.wrap_source(log)
        runs.append(([*feed], dict(clock.injected)))
    assert runs[0] == runs[1], "same plan, same workload, different faults"
    inj = runs[0][1]
    assert inj["dup"] >= 1 and inj["drop"] >= 1 and inj["reorder"] >= 1

    clock = plan.clock()
    replay = ExactlyOnceReplay(log, clock.wrap_source(log))
    assert list(replay) == list(log)
    assert replay.refetched >= 1 and replay.duplicates_suppressed >= 1

    # mid-log start offset: the recovery path's replay cut
    start = len(log) // 2
    replay = ExactlyOnceReplay(log, clock.wrap_source(log, start=start),
                               start=start)
    assert list(replay) == list(log)[start:]


@pytest.mark.parametrize("kind,field", [("nan", 0), ("inf", 1),
                                        ("negative", 0)])
def test_poisoned_patches_die_at_the_validation_wall(small_platform,
                                                     kind, field):
    g, act = small_platform
    host = HostOperators.from_graph(g, act)
    clock = FaultPlan(seed=5, poison_kind=kind).clock()
    users = np.arange(6)
    pu, pl, pm = clock.poison_patch(users, host.lam[users], host.mu[users])
    bad = pl if field == 0 else pm
    assert not np.all(np.isfinite(bad) & (bad >= 0))
    with pytest.raises(ValueError):
        host.patch_activity(pu, lam=pl, mu=pm)


# --------------------------------------------------------------------- #
# Sentinels + quarantine
# --------------------------------------------------------------------- #
def test_sentinels_trip_on_the_right_symptoms(small_platform):
    g, act = small_platform
    s = Sentinels(gap_window=3)
    assert s.check_array("psi", np.ones(4)) is None
    assert s.check_array("psi", np.asarray([1.0, np.nan])).kind == "non_finite"
    assert s.check_gap(float("inf")).kind == "non_finite"
    s.reset_gap()
    trips = [s.check_gap(gap) for gap in (1.0, 2.0, 3.0, 4.0)]
    assert trips[:3] == [None, None, None]
    assert trips[3].kind == "gap_growth"
    host = HostOperators.from_graph(g, act)
    a = alpha_norm(host)
    assert 0.0 < a < 1.0
    assert Sentinels(alpha_max=a * 0.9).check_alpha(host).kind == "alpha"
    bound = psi_residual_bound(host, 1e-6)
    assert bound is not None and 0.0 < bound < 1e-3
    assert psi_residual_bound(host, float("nan")) is None


def test_lane_quarantine_freezes_one_tenant_not_the_fleet(small_platform):
    g0, act0 = small_platform
    g1 = powerlaw_configuration(140, 900, seed=21)
    act1 = heterogeneous(g1.n, seed=22)
    fleet = TenantFleet(backend="reference", tol=1e-8,
                        policy=BucketPolicy((512,), edge_quantum=4096))
    fleet.admit("t0", g0, act0)
    fleet.admit("t1", g1, act1)
    fleet.solve()
    before = fleet.psi("t0").copy()
    quar = LaneQuarantine(fleet, sentinels=Sentinels(alpha_max=0.999))

    # NaN-poison: rejected at the wall, lane frozen serving last-good
    clock = FaultPlan(seed=9, poison_kind="nan").clock()
    users = np.arange(4)
    host0 = fleet._rec("t0").host
    pu, pl, pm = clock.poison_patch(users, host0.lam[users], host0.mu[users])
    assert not quar.patch_activity("t0", pu, lam=pl, mu=pm)
    assert quar.is_frozen("t0") and quar.frozen == ("t0",)
    assert np.array_equal(quar.psi("t0"), before)
    # further patches to the frozen lane are refused outright
    assert not quar.patch_activity("t0", np.asarray([2]),
                                   lam=np.asarray([0.5]))

    # the co-tenant stays fully live
    assert quar.patch_activity("t1", np.asarray([5]), mu=np.asarray([0.9]))
    assert not quar.is_frozen("t1")
    idx, top = quar.top_k("t1", 5)
    assert idx.shape == (5,) and np.all(np.diff(top) <= 0)

    # α-poison passes validation but is reverted + frozen by the sentinel
    quar.unfreeze("t0")
    lam0, mu0 = host0.lam.copy(), host0.mu.copy()
    assert not quar.patch_activity("t0", np.asarray([3]),
                                   mu=np.asarray([1e12]))
    assert quar.is_frozen("t0") and quar.reverted_patches == 1
    assert np.array_equal(host0.lam, lam0) and np.array_equal(host0.mu, mu0)


def test_service_guard_rolls_back_to_last_checkpoint(small_platform):
    g, act = small_platform
    with tempfile.TemporaryDirectory() as d:
        svc = PsiService(g, act, tol=1e-8, max_iter=400)
        guard = ServiceGuard(svc, d, sentinels=Sentinels(alpha_max=0.999))
        assert guard.update_activity(np.asarray([4]), lam=np.asarray([1.3]))
        good = guard.scores().copy()

        # validation-wall rejection leaves the service serving, untouched
        assert not guard.update_activity(np.asarray([4]),
                                         lam=np.asarray([np.nan]))
        assert guard.rejected_patches == 1
        assert np.array_equal(guard.scores(), good)

        # α-poison passes validation; the post-resolve sentinel trips and
        # the guard rolls back to the last complete checkpoint
        assert not guard.update_activity(np.asarray([2]),
                                         mu=np.asarray([1e12]))
        assert guard.rollbacks == 1
        assert np.abs(guard.scores() - good).max() <= 1e-6


# --------------------------------------------------------------------- #
# Supervisor ladder
# --------------------------------------------------------------------- #
def _hanging_driver(g, act, hang_budget, **kw):
    def delay(chunk, epoch):
        if hang_budget[0] > 0 and chunk == 0:
            hang_budget[0] -= 1
            return 1.0
        return 0.0

    return AsyncPsiDriver(g, act, num_chunks=2, tau=1, delay_hook=delay, **kw)


def test_supervisor_retry_absorbs_a_transient_hang(small_platform):
    g, act = small_platform
    budget = [0]
    sup = ResilientResolver(_hanging_driver(g, act, budget), tol=1e-7,
                            attempt_deadline_s=0.35, max_retries=1,
                            backoff_s=0.01, allow_rechunk=False,
                            allow_sync=False)
    budget[0] = 1
    out = sup.resolve(warm=False)
    assert not out.degraded and out.escalation == "retry"
    assert out.attempts == 2 and sup.report.retries == 1
    assert sup.report.recoveries == 1 and sup.report.mttr_s > 0
    assert out.psi_error_bound is not None


def test_supervisor_escalates_to_tau_tightened_rechunk(small_platform):
    g, act = small_platform
    budget = [1]                            # one hang: sinks attempt 1 only
    sup = ResilientResolver(_hanging_driver(g, act, budget), tol=1e-7,
                            attempt_deadline_s=0.3, max_retries=0,
                            allow_rechunk=True, allow_sync=False)
    out = sup.resolve(warm=False)
    # retries exhausted -> the pipeline is rebuilt barriered (tau = 0)
    assert not out.degraded and out.escalation == "rechunk"
    assert sup.driver.tau == 0 and sup.report.escalations == ["rechunk"]


def test_supervisor_sync_rung_and_degraded_tagging(small_platform):
    g, act = small_platform
    psi_true = np.asarray(make_engine("reference", graph=g, activity=act)
                          .run(tol=1e-9).psi)
    budget = [10 ** 9]
    sup = ResilientResolver(_hanging_driver(g, act, budget), tol=1e-7,
                            attempt_deadline_s=0.3, max_retries=0,
                            allow_rechunk=False, allow_sync=True)
    out = sup.resolve(warm=False)
    assert not out.degraded and out.escalation == "sync"
    assert np.abs(np.asarray(out.psi) - psi_true).max() <= 1e-5
    assert out.psi_error_bound is not None and out.psi_error_bound < 1e-3

    # now every live rung is off: serve degraded from the sync result,
    # honestly tagged with staleness + the certified error bound
    sup.allow_sync = False
    degraded = sup.resolve(warm=False)
    assert degraded.degraded and degraded.escalation == "degraded"
    assert degraded.freshness is not None
    assert degraded.freshness.staleness_seconds >= 0.0
    assert degraded.freshness.psi_error_bound == degraded.psi_error_bound
    assert degraded.ranking.err_bound == degraded.psi_error_bound
    assert np.array_equal(degraded.psi, out.psi)
    assert sup.report.degraded_served == 1
    budget[0] = 0


def test_degrade_with_no_prior_fixed_point_raises(small_platform):
    from repro.resilience import ResolveFailure
    g, act = small_platform
    budget = [10 ** 9]
    sup = ResilientResolver(_hanging_driver(g, act, budget), tol=1e-7,
                            attempt_deadline_s=0.25, max_retries=0,
                            allow_rechunk=False, allow_sync=False)
    with pytest.raises(ResolveFailure):
        sup.resolve(warm=False)
    budget[0] = 0


# --------------------------------------------------------------------- #
# The whole stack: seeded chaos → recovery → fixed-point parity
# --------------------------------------------------------------------- #
def test_chaos_recovery_reaches_fault_free_fixed_point_f32():
    report, metrics = run_chaos(n=150, m=900, horizon=2.5, seed=1)
    assert not report.unsurvived
    assert metrics["parity_err"] <= metrics["psi_tol"]
    assert metrics["restarts"] >= 1 and metrics["offset"] > 0
    assert report.degraded_served >= 1 and report.recoveries >= 1


def test_chaos_check_passes_under_x64():
    """The acceptance gate: f64 recovered-vs-oracle ψ parity ≤ 1e-12,
    zero unsurvived faults — in a spawned x64 process (pytest runs f32)."""
    env = dict(os.environ, JAX_ENABLE_X64="1", PYTHONPATH=_SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.resilience.check",
         "--n", "200", "--m", "1200", "--horizon", "3"],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    assert "dtype=float64" in out.stdout
    assert "[resilience-check] PASS" in out.stdout
