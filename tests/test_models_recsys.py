"""MIND + embedding substrate tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models.recsys import mind
from repro.models.recsys.embedding import embedding_bag
from repro.train import adamw, constant_schedule


@pytest.fixture(scope="module")
def setup():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_arch("mind").config(reduced=True)
    params = mind.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B = 8
    batch = dict(
        hist_ids=jnp.asarray(rng.integers(0, cfg.n_items, (B, cfg.hist_len))),
        hist_mask=jnp.asarray(rng.random((B, cfg.hist_len)) > 0.2),
        profile_ids=jnp.asarray(rng.integers(0, cfg.n_profile, (B * 4,))),
        profile_bags=jnp.asarray(np.repeat(np.arange(B), 4)),
        pos_ids=jnp.asarray(rng.integers(0, cfg.n_items, (B,))),
        neg_ids=jnp.asarray(rng.integers(0, cfg.n_items, (B, cfg.n_neg))))
    return mesh, cfg, params, batch


def test_train_converges(setup):
    mesh, cfg, params, batch = setup
    opt = adamw(constant_schedule(1e-2))
    state = opt.init(params)

    @jax.jit
    def step(p, st, b):
        loss, g = jax.value_and_grad(mind.train_loss)(p, b, cfg, mesh)
        p, st = opt.apply(g, st, p)
        return p, st, loss

    losses = []
    for _ in range(15):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] and np.isfinite(losses[-1])


def test_interests_shape_and_norm(setup):
    mesh, cfg, params, batch = setup
    u = mind.user_interests(params, batch["hist_ids"], batch["hist_mask"],
                            batch["profile_ids"], batch["profile_bags"],
                            cfg, mesh)
    assert u.shape == (8, cfg.n_interests, cfg.embed_dim)
    assert np.all(np.isfinite(np.asarray(u)))


def test_capsule_routing_mask(setup):
    """Fully-masked history must not produce NaNs (softmax over −inf)."""
    mesh, cfg, params, batch = setup
    mask = jnp.zeros_like(batch["hist_mask"])
    u = mind.user_interests(params, batch["hist_ids"], mask,
                            batch["profile_ids"], batch["profile_bags"],
                            cfg, mesh)
    assert np.all(np.isfinite(np.asarray(u)))


def test_retrieval_is_batched_dot(setup):
    mesh, cfg, params, batch = setup
    u = mind.user_interests(params, batch["hist_ids"], batch["hist_mask"],
                            batch["profile_ids"], batch["profile_bags"],
                            cfg, mesh)
    cands = jnp.arange(cfg.n_items, dtype=jnp.int32)
    scores = mind.retrieval_scores(params, u[0], cands, cfg, mesh)
    assert scores.shape == (cfg.n_items,)
    # max over interests: score >= each individual interest dot
    e = params["item_emb"]
    per = np.asarray(e @ np.asarray(u[0]).T)
    np.testing.assert_allclose(np.asarray(scores), per.max(axis=1),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_modes():
    tbl = jnp.asarray(np.arange(40, dtype=np.float32).reshape(10, 4))
    ids = jnp.asarray([0, 1, 10, 5])       # 10 = sentinel
    bags = jnp.asarray([0, 0, 1, 2])
    s = embedding_bag(tbl, ids, bags, 3, mode="sum")
    m = embedding_bag(tbl, ids, bags, 3, mode="mean")
    np.testing.assert_allclose(np.asarray(s[0]),
                               np.asarray(tbl[0] + tbl[1]))
    np.testing.assert_allclose(np.asarray(m[0]),
                               np.asarray((tbl[0] + tbl[1]) / 2))
    np.testing.assert_allclose(np.asarray(s[1]), 0.0)   # sentinel-only bag
