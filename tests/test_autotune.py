"""Regime autotuner: cost model, plan cache, auto/accelerated engine loops."""
import numpy as np
import pytest

from repro.graphs import clustered_blocks, erdos_renyi, powerlaw_configuration
from repro.kernels import autotune
from repro.kernels.autotune import (BSR_MIN_OCCUPANCY, PlanCache, RegimePlan,
                                    bsr_occupancy, choose_solver, plan_regime,
                                    estimate_bsr_cost,
                                    estimate_edge_tile_cost)
from repro.kernels.formats import build_bsr, build_edge_tiles


@pytest.fixture(scope="module")
def sparse_graph():
    return powerlaw_configuration(1000, 7000, seed=17)


@pytest.fixture(scope="module")
def clustered_graph():
    return clustered_blocks(512, 24_000, block=128, p_in=1.0, seed=3)


def test_model_picks_edge_tile_for_hyper_sparse(sparse_graph):
    plan = plan_regime(sparse_graph, cache=None)
    assert plan.regime == "edge_tile"
    assert build_bsr(sparse_graph).occupancy < 0.05


def test_model_picks_bsr_for_dense_clusters(clustered_graph):
    plan = plan_regime(clustered_graph, cache=None)
    assert plan.regime == "bsr"
    assert build_bsr(clustered_graph).occupancy > 0.2


def test_cost_model_tracks_padding_waste(sparse_graph):
    """The edge-tile estimate must charge for block padding: a tiny eblk
    wastes less on a hyper-sparse graph than a huge one."""
    g = sparse_graph
    small = estimate_edge_tile_cost(g, tile=256, e1=8, e2=128)
    # an (unrealistically) large edge block pads every node tile up to it
    huge = estimate_edge_tile_cost(g, tile=256, e1=64, e2=128)
    assert small < huge
    fmt = build_edge_tiles(g, tile=256, e1=8, e2=128)
    assert small >= fmt.num_blocks * fmt.eblk * 12   # ≥ the slot traffic


def test_bsr_cost_scales_with_materialized_blocks(sparse_graph,
                                                  clustered_graph):
    cs = estimate_bsr_cost(sparse_graph, ts=128, td=128)
    cc = estimate_bsr_cost(clustered_graph, ts=128, td=128)
    # the sparse graph materializes nearly every block at 7k edges; the
    # block-diagonal graph touches only its diagonal
    assert cs / sparse_graph.m > cc / clustered_graph.m


def test_plan_cache_stable_under_structure_not_activity(sparse_graph):
    cache = PlanCache()
    p1 = plan_regime(sparse_graph, cache=cache)
    p2 = plan_regime(sparse_graph, cache=cache)
    assert p1 == p2
    assert (cache.hits, cache.misses) == (1, 1)
    # a different structure misses
    plan_regime(erdos_renyi(200, 900, seed=1), cache=cache)
    assert (cache.hits, cache.misses) == (1, 2)


def test_plan_cache_key_includes_candidates(sparse_graph):
    cache = PlanCache()
    plan_regime(sparse_graph, cache=cache)
    plan_regime(sparse_graph, cache=cache,
                edge_tile_candidates=((128, 8, 128),))
    assert cache.misses == 2                 # different search space


def test_microbench_returns_measured_plan(clustered_graph):
    plan = plan_regime(clustered_graph, microbench=True, cache=None)
    assert plan.measured_us > 0
    # on this graph model and measurement agree: dense diagonal → BSR
    assert plan.regime == "bsr"


def test_plan_params_roundtrip():
    et = RegimePlan(regime="edge_tile", tile=128, e1=8, e2=128)
    assert et.params() == dict(tile=128, e1=8, e2=128)
    bs = RegimePlan(regime="bsr", ts=128, td=256)
    assert bs.params() == dict(ts=128, td=256)


def test_clustered_blocks_rejects_infeasible_m():
    """More edges than the block structure can host must fail fast, not
    retry-oversample forever."""
    with pytest.raises(ValueError, match="exceeds"):
        clustered_blocks(256, 70_000, block=128, p_in=1.0)


def test_global_cache_is_default(sparse_graph):
    autotune.PLAN_CACHE.clear()
    plan_regime(sparse_graph)
    plan_regime(sparse_graph)
    assert autotune.PLAN_CACHE.hits == 1
    autotune.PLAN_CACHE.clear()


# --------------------------------------------------------------------- #
# BSR density pruning + solver-level choice (push vs global)
# --------------------------------------------------------------------- #
def test_bsr_occupancy_matches_format(sparse_graph, clustered_graph):
    """The O(M) estimate must agree with the materialized format's ratio."""
    for g in (sparse_graph, clustered_graph):
        est = bsr_occupancy(g, ts=128, td=128)
        assert est == pytest.approx(build_bsr(g).occupancy, rel=1e-12)
    assert bsr_occupancy(sparse_graph, ts=128, td=128) < BSR_MIN_OCCUPANCY
    assert bsr_occupancy(clustered_graph, ts=128, td=128) > BSR_MIN_OCCUPANCY


def test_microbench_prunes_hypersparse_bsr(sparse_graph, clustered_graph,
                                           monkeypatch):
    """The regression the planner latency depends on: on a hyper-sparse
    graph no BSR candidate may reach the microbench (building + timing a
    near-empty 128×128 tile format costs orders of magnitude more than the
    step it measures), while a clustered graph still times and picks BSR."""
    timed = []

    def fake_bench(graph, plan, dtype, interpret):
        timed.append(plan.regime)
        return 1.0 if plan.regime == "bsr" else 2.0   # bsr "wins" if timed
    monkeypatch.setattr(autotune, "_microbench_step", fake_bench)

    timed.clear()
    plan = plan_regime(sparse_graph, microbench=True, cache=None)
    assert "bsr" not in timed
    assert plan.regime == "edge_tile"

    timed.clear()
    plan = plan_regime(clustered_graph, microbench=True, cache=None)
    assert "bsr" in timed
    assert plan.regime == "bsr"


def test_choose_solver_local_query_picks_push(sparse_graph):
    c = choose_solver(sparse_graph, dirty_frac=0.001, k_frac=0.01)
    assert c.solver == "push"
    assert c.push_edges < c.global_edges


def test_choose_solver_global_query_picks_sweep(sparse_graph):
    c = choose_solver(sparse_graph, dirty_frac=1.0, k_frac=1.0)
    assert c.solver == "global"
    assert c.push_edges >= c.global_edges


def test_choose_solver_validates():
    g = erdos_renyi(50, 100, seed=0)
    with pytest.raises(ValueError, match="dirty_frac"):
        choose_solver(g, dirty_frac=1.5)
    with pytest.raises(ValueError, match="k_frac"):
        choose_solver(g, dirty_frac=0.1, k_frac=0.0)
    with pytest.raises(ValueError, match="sweeps"):
        choose_solver(g, dirty_frac=0.1, sweeps=0)


# --------------------------------------------------------------------- #
# Golden decision table + monotonicity (PR 10)
# --------------------------------------------------------------------- #
DIRTY_GRID = (0.0001, 0.001, 0.01, 0.1, 0.5, 1.0)
K_GRID = (0.0001, 0.001, 0.01, 0.1, 1.0)

# choose_solver(n=100_000, m=1_500_000) over DIRTY_GRID × K_GRID. The
# frontier saturates within a few rounds at mean degree 15, so push's
# edge-work is rounds·m with rounds < sweeps whenever k_frac < 1 — the
# global sweep only wins at the exhaustive corner (everything dirty AND
# the full ranking requested). Pinned: a cost-model change that moves
# any cell is a planner behavior change and must be deliberate.
GOLDEN_SOLVER_TABLE = {
    0.0001: ("push", "push", "push", "push", "push"),
    0.001: ("push", "push", "push", "push", "push"),
    0.01: ("push", "push", "push", "push", "push"),
    0.1: ("push", "push", "push", "push", "push"),
    0.5: ("push", "push", "push", "push", "push"),
    1.0: ("push", "push", "push", "push", "global"),
}


class _Shape:
    n, m = 100_000, 1_500_000


def test_choose_solver_golden_decision_table():
    for dirty, want in GOLDEN_SOLVER_TABLE.items():
        got = tuple(choose_solver(_Shape, dirty_frac=dirty, k_frac=k).solver
                    for k in K_GRID)
        assert got == want, f"dirty_frac={dirty}: {got} != {want}"


def test_choose_solver_monotone_in_dirty_frac_sweep():
    """Deterministic sweep of the hypothesis property below: more dirt
    never makes push cheaper, so the choice can only flip push→global as
    dirty_frac grows (never back)."""
    for k in K_GRID:
        prev_edges, seen_global = -1.0, False
        for dirty in DIRTY_GRID:
            c = choose_solver(_Shape, dirty_frac=dirty, k_frac=k)
            assert c.push_edges >= prev_edges
            if seen_global:
                assert c.solver == "global"
            seen_global = c.solver == "global"
            prev_edges = c.push_edges


def test_choose_solver_monotone_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(n=st.integers(10, 10**6),
               deg=st.floats(0.1, 64.0),
               k_frac=st.floats(1e-6, 1.0),
               lo=st.floats(0.0, 1.0), hi=st.floats(0.0, 1.0))
    @hyp.settings(deadline=None, max_examples=200)
    def prop(n, deg, k_frac, lo, hi):
        lo, hi = min(lo, hi), max(lo, hi)
        g = type("G", (), dict(n=n, m=int(n * deg)))
        a = choose_solver(g, dirty_frac=lo, k_frac=k_frac)
        b = choose_solver(g, dirty_frac=hi, k_frac=k_frac)
        assert b.push_edges >= a.push_edges
        if a.solver == "global":          # flips at most once, push→global
            assert b.solver == "global"

    prop()


def test_plan_source_provenance(sparse_graph, clustered_graph, monkeypatch):
    assert plan_regime(sparse_graph, cache=None,
                       calibration=None).source == "model"
    monkeypatch.setattr(autotune, "_microbench_step",
                        lambda graph, plan, dtype, interpret: 1.0)
    bench = plan_regime(clustered_graph, cache=None, microbench=True,
                        calibration=None)
    assert bench.source == "microbench"
    # the memoized copy keeps its provenance on a later cache hit
    cache = PlanCache()
    plan_regime(sparse_graph, cache=cache, calibration=None)
    assert cache.lookup(next(iter(cache._plans))).source == "model"
