"""Telemetry plane units: exposition golden, span nesting + thread
safety under the async scheduler, null-path overhead, the retrace guard,
and the instrumented-vs-disabled bitwise parity contract."""
import json
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import convergence as obs_convergence
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry, NullRegistry


@pytest.fixture
def fresh_obs():
    """Isolated sinks (registry + tracker + in-memory tracer) per test."""
    prev = obs.configure(registry=MetricsRegistry(),
                         tracer=obs.Tracer(None),
                         tracker=obs.ConvergenceTracker())
    obs_log.clear()
    yield obs_metrics.get_registry()
    obs.restore(prev)


# --------------------------------------------------------------------- #
# exposition
# --------------------------------------------------------------------- #
def test_prometheus_exposition_golden():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests served", ("code",))
    c.labels(code="200").inc()
    c.labels(code="500").inc(2)
    reg.gauge("temperature", "current reading").set(1.5)
    h = reg.histogram("latency_seconds", "request wall",
                      buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert reg.to_prometheus() == (
        "# HELP latency_seconds request wall\n"
        "# TYPE latency_seconds histogram\n"
        'latency_seconds_bucket{le="0.1"} 1\n'
        'latency_seconds_bucket{le="1"} 2\n'
        'latency_seconds_bucket{le="+Inf"} 3\n'
        "latency_seconds_sum 5.55\n"
        "latency_seconds_count 3\n"
        "# HELP requests_total requests served\n"
        "# TYPE requests_total counter\n"
        'requests_total{code="200"} 1\n'
        'requests_total{code="500"} 2\n'
        "# HELP temperature current reading\n"
        "# TYPE temperature gauge\n"
        "temperature 1.5\n")


def test_json_exposition_quantiles_and_roundtrip():
    reg = MetricsRegistry()
    h = reg.histogram("h", "", buckets=(1.0, 10.0, 100.0))
    for v in range(1, 101):
        h.observe(float(v))
    doc = json.loads(json.dumps(reg.to_json()))
    ser = doc["h"]["series"][0]
    assert ser["count"] == 100 and ser["min"] == 1.0 and ser["max"] == 100.0
    assert ser["p50"] <= ser["p90"] <= ser["p99"] <= 100.0
    # p50 of 1..100 must land inside the (1, 10] / (10, 100] boundary zone
    assert 10.0 <= ser["p50"] <= 100.0


def test_registry_conflicting_redeclaration_raises():
    reg = MetricsRegistry()
    reg.counter("x", "a counter")
    reg.counter("x")                        # idempotent re-use is fine
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.counter("x", labelnames=("k",))


def test_metrics_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("n", "")
    h = reg.histogram("d", "")

    def work():
        for _ in range(1_000):
            c.inc()
            h.observe(0.001)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8_000
    assert reg.get("d").merged().count == 8_000


# --------------------------------------------------------------------- #
# spans
# --------------------------------------------------------------------- #
def test_span_nesting_and_async_thread_safety(fresh_obs):
    from repro.asyncexec import AsyncPsiDriver
    from repro.core import heterogeneous
    from repro.graphs import powerlaw_configuration

    g = powerlaw_configuration(1_000, 6_000, seed=3)
    act = heterogeneous(g.n, seed=4)
    rep = AsyncPsiDriver(g, act, num_chunks=4, tau=2).run(tol=1e-6)
    assert rep.converged
    tracer = obs_trace.get_tracer()
    spans = list(tracer.spans)
    by_id = {s["id"]: s for s in spans}
    steps = [s for s in spans if s["name"] == "async.step"]
    assert steps, "worker threads emitted no async.step spans"
    assert len({s["thread"] for s in steps}) >= 2, \
        "async.step spans should come from multiple worker threads"
    for s in spans:
        if s.get("parent"):
            parent = by_id[s["parent"]]
            # nesting is per-thread: a child lives inside its parent's
            # window on the shared clock
            assert parent["thread"] == s["thread"]
            assert parent["ts"] <= s["ts"] + 1e-9
            assert s["depth"] == parent["depth"] + 1
    # the driver's convergence record carries a real gap trajectory
    recs = obs_convergence.get_tracker().series()
    drv = [r for r in recs if r.backend == "async_driver"]
    assert drv and len(drv[-1].points) >= 1
    assert drv[-1].converged


def test_span_measures_without_tracer():
    """Spans on the NULL_TRACER still measure (drivers consume
    duration_s) — they just record nothing."""
    with obs_trace.span("anything") as sp:
        time.sleep(0.01)
    assert sp.duration_s >= 0.008


def test_tracer_jsonl_and_chrome_export(tmp_path):
    path = str(tmp_path / "t.jsonl")
    prev = obs.configure(tracer=obs.Tracer(path))
    try:
        with obs_trace.span("outer", k=1):
            with obs_trace.span("inner"):
                pass
        obs_trace.get_tracer().flush()
    finally:
        obs.restore(prev)
    lines = [json.loads(ln) for ln in open(path)]
    assert [ln["name"] for ln in lines] == ["inner", "outer"]
    assert lines[0]["parent"] == lines[1]["id"]
    chrome = str(tmp_path / "t.chrome.json")
    tracer = obs.Tracer(None)
    prev = obs.configure(tracer=tracer)
    try:
        with obs_trace.span("solo"):
            pass
    finally:
        obs.restore(prev)
    tracer.export_chrome(chrome)
    doc = json.load(open(chrome))
    assert any(e.get("name") == "solo" for e in doc["traceEvents"])


# --------------------------------------------------------------------- #
# disabled path
# --------------------------------------------------------------------- #
def test_null_registry_is_cheap_and_inert():
    prev = obs.disable()
    try:
        assert not obs.enabled()
        reg = obs_metrics.get_registry()
        assert getattr(reg, "null", False)
        t0 = time.perf_counter()
        for _ in range(200_000):
            obs_metrics.counter("hot_path_total").inc()
        per_op = (time.perf_counter() - t0) / 200_000
        # one attribute access + one no-op call; generous CI bound
        assert per_op < 5e-6, f"null counter costs {per_op * 1e6:.2f}us/op"
        assert reg.to_prometheus() == "" and reg.to_json() == {}
        assert obs_convergence.begin("reference") is None
        obs_convergence.finish(None, gap=0.0)      # must not raise
    finally:
        obs.restore(prev)


# --------------------------------------------------------------------- #
# retrace guard
# --------------------------------------------------------------------- #
def test_retrace_guard_counts_forced_recompile(fresh_obs):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        return x * 2.0

    guarded = obs_trace.retrace_guard(f, name="unit.f")
    guarded(jnp.ones((3,)))                 # first compile: expected
    assert guarded.retraces == 0
    guarded(jnp.ones((4,)))                 # shape change: silent retrace
    assert guarded.retraces == 1
    assert fresh_obs.value("psi_retraces_total", fn="unit.f") == 1.0
    events = obs_log.recent(10, name="retrace")
    assert events and events[-1]["fn"] == "unit.f"


# --------------------------------------------------------------------- #
# structured warnings
# --------------------------------------------------------------------- #
def test_obs_log_warn_still_warns(fresh_obs):
    with pytest.warns(RuntimeWarning, match="something torn"):
        obs_log.warn("unit_event", "something torn", step=9)
    ev = obs_log.recent(5, name="unit_event")
    assert ev and ev[-1]["level"] == "warning"
    assert ev[-1]["step"] == 9
    assert fresh_obs.value("obs_events_total",
                           event="unit_event", level="warning") == 1.0


def test_checkpoint_corruption_routes_through_obs(fresh_obs):
    from repro.ckpt import checkpoint
    import jax.numpy as jnp

    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, dict(x=jnp.ones((3,))))
        checkpoint.save(d, 2, dict(x=jnp.ones((3,))))
        with open(os.path.join(d, "step_00000002", "MANIFEST.json"),
                  "w") as f:
            f.write("{ torn")
        with pytest.warns(RuntimeWarning, match="corrupt or incomplete"):
            assert checkpoint.latest_step(d) == 1
    assert obs_log.recent(5, name="ckpt_corrupt_step")


# --------------------------------------------------------------------- #
# parity: instrumentation only ever reads
# --------------------------------------------------------------------- #
def test_instrumented_psi_bitwise_parity():
    from repro.core import heterogeneous, make_engine
    from repro.graphs import powerlaw_configuration

    g = powerlaw_configuration(800, 4_800, seed=11)
    act = heterogeneous(g.n, seed=12)

    def solve():
        return np.array(
            make_engine("reference", graph=g, activity=act).run(tol=1e-8).psi,
            copy=True)

    prev = obs.configure(registry=MetricsRegistry(),
                         tracer=obs.Tracer(None),
                         tracker=obs.ConvergenceTracker())
    try:
        live = solve()
        assert obs_metrics.get_registry().value(
            "psi_resolves_total", backend="reference") == 1.0
    finally:
        obs.restore(prev)
    prev = obs.disable()
    try:
        dark = solve()
    finally:
        obs.restore(prev)
    assert np.array_equal(live, dark), \
        "instrumentation changed the computed fixed point"


def test_query_metrics_and_cache_hit_ratio(fresh_obs):
    from repro.core import PsiService, heterogeneous
    from repro.graphs import powerlaw_configuration

    g = powerlaw_configuration(600, 3_600, seed=21)
    act = heterogeneous(g.n, seed=22)
    svc = PsiService(g, act, tol=1e-8, backend="reference")
    svc.top_k(3)                             # miss: first resolve
    svc.top_k(3)                             # hit: cached ranking
    svc.scores_batch(np.arange(4))           # hit
    reg = fresh_obs
    hits = reg.value("psi_query_cache_total", result="hit") or 0
    misses = reg.value("psi_query_cache_total", result="miss") or 0
    assert misses >= 1 and hits >= 2
    pooled = reg.get("psi_query_seconds").merged()
    assert pooled.count == hits + misses
    assert pooled.quantile(0.5) <= pooled.quantile(0.99)
