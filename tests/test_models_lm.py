"""Per-arch LM smoke tests (reduced configs) + attention path parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models.transformer import (LMConfig, MoECfg, init_params, forward,
                                      make_train_step, make_prefill,
                                      make_decode_step, init_cache,
                                      count_params)
from repro.models.transformer.attention import _blocked, _banded, _dense
from repro.train import adamw, constant_schedule

LM_ARCHS = ["tinyllama-1.1b", "yi-9b", "nemotron-4-340b", "mixtral-8x22b",
            "mixtral-8x7b"]


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_train_step(arch, mesh):
    """One forward/train step on CPU: output shapes + no NaNs."""
    cfg = get_arch(arch).config(reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant_schedule(1e-3))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, mesh, opt))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 17)))
    batch = dict(tokens=toks[:, :-1], labels=toks[:, 1:])
    losses = []
    for _ in range(5):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mixtral-8x7b"])
def test_prefill_then_decode_matches_forward(arch, mesh):
    cfg = get_arch(arch).config(reduced=True)
    if cfg.moe:  # avoid capacity drops for exact parity
        cfg = type(cfg)(**{**cfg.__dict__,
                           "moe": MoECfg(cfg.moe.n_experts, cfg.moe.top_k,
                                         capacity_factor=8.0)})
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    S = 12
    seq = jnp.asarray(rng.integers(0, cfg.vocab, (2, S + 4)))
    logits_full = forward(params, seq, cfg, mesh)
    prefill = jax.jit(make_prefill(cfg, mesh, max_len=S + 4))
    decode = jax.jit(make_decode_step(cfg, mesh))
    cache, lg = prefill(params, seq[:, :S])
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(S, S + 4):
        cache, lg = decode(params, cache, seq[:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_param_count_formula():
    cfg = get_arch("tinyllama-1.1b").config()
    n = count_params(cfg)
    assert 1.0e9 < n < 1.25e9          # ~1.1B
    cfg = get_arch("mixtral-8x7b").config()
    assert 44e9 < count_params(cfg) < 49e9   # ~46.7B total


def test_blocked_attention_equals_dense():
    rng = np.random.default_rng(0)
    b, s, hq, hkv, dh = 2, 512, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, hkv, hq // hkv, dh))
                    .astype("float32"))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype("float32"))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype("float32"))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    dense = _dense(q, k, v, pos, pos, None, None)
    blocked = _blocked(q, k, v, pos, pos, None, 128, 64)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_banded_swa_equals_dense_window():
    rng = np.random.default_rng(1)
    b, s, hkv, g, dh, w = 1, 1024, 2, 2, 16, 64
    q = jnp.asarray(rng.normal(size=(b, s, hkv, g, dh)).astype("float32"))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype("float32"))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype("float32"))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    dense = _dense(q, k, v, pos, pos, w, None)
    banded = _banded(q, k, v, pos, pos, w, 128)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_moe_capacity_drops_tokens():
    """cap < load ⇒ overflow tokens are dropped (GShard semantics)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = LMConfig(name="m", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                   d_ff=64, vocab=64, moe=MoECfg(2, 2, capacity_factor=0.1),
                   dtype=jnp.float32, param_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 16)))
    out = forward(params, toks, cfg, mesh)
    assert np.all(np.isfinite(np.asarray(out)))
