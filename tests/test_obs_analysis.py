"""Units for the obs analysis-and-control layer (PR 9): SLO engine +
burn-rate alerting, span-stream profiler, convergence watch +
pre-emptive supervision, and the noise-aware perf-regression gate."""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs.convergence import ResolveRecord
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import Profile
from repro.obs.regress import gate, inject_slowdown
from repro.obs.regress import main as regress_main
from repro.obs.slo import (BurnRule, SLO, SLOEngine, counter_ratio,
                           default_slos, gauge_value, histogram_quantile)
from repro.obs.watch import ConvergenceWatch


@pytest.fixture
def fresh_obs():
    """Isolated sinks (registry + tracker + in-memory tracer) per test."""
    prev = obs.configure(registry=MetricsRegistry(),
                         tracer=obs.Tracer(None),
                         tracker=obs.ConvergenceTracker())
    obs_log.clear()
    yield obs_metrics.get_registry()
    obs.restore(prev)


# --------------------------------------------------------------------- #
# SLO engine
# --------------------------------------------------------------------- #
def test_signal_helpers_read_live_registry(fresh_obs):
    reg = fresh_obs
    h = reg.histogram("lat_seconds", "latency")
    for v in (0.01, 0.02, 0.03):
        h.observe(v)
    reg.gauge("lag_s", "lag", ("lane",)).labels(lane="a").set(4.0)
    reg.counter("bad_total", "bad").inc(1)
    reg.counter("all_total", "all").inc(4)
    assert histogram_quantile("lat_seconds", 1.0)() == pytest.approx(0.03)
    assert gauge_value("lag_s", lane="a")() == 4.0
    assert counter_ratio("bad_total", "all_total")() == pytest.approx(0.25)
    # absent series: None, never an exception
    assert histogram_quantile("nope_seconds", 0.99)() is None
    assert gauge_value("nope")() is None
    assert counter_ratio("bad_total", "nope_total")() is None


def test_slo_no_data_is_compliant_and_counted_as_good(fresh_obs):
    t = [0.0]
    eng = SLOEngine([SLO("s", lambda: None, target=1.0)],
                    clock=lambda: t[0])
    eng.tick()
    row = eng.report()["slos"][0]
    assert row["samples"] == 1 and row["bad_samples"] == 0
    assert row["meeting_target"] and row["budget_remaining"] == 1.0


def test_slo_violations_drain_the_error_budget(fresh_obs):
    t = [0.0]
    eng = SLOEngine([SLO("lat", lambda: 2.0, target=1.0,
                         objective=0.99)], clock=lambda: t[0])
    for _ in range(3):
        eng.tick()
        t[0] += 1.0
    rep = eng.report()
    row = rep["slos"][0]
    assert row["bad_samples"] == 3 and not row["meeting_target"]
    assert row["budget_remaining"] == 0.0 and not rep["ok"]
    fam = fresh_obs.get("psi_slo_violations_total")
    assert sum(ch.value for _, ch in fam.children()) == 3


def test_higher_is_better_objective_direction(fresh_obs):
    eng = SLOEngine([SLO("throughput", lambda: 80.0, target=100.0,
                         op=">=")], clock=lambda: 0.0)
    eng.tick()
    assert not eng.report()["slos"][0]["meeting_target"]


def test_burn_alert_needs_both_windows_and_fires_once(fresh_obs):
    t = [0.0]
    val = [0.0]
    slo = SLO("s", lambda: val[0], target=1.0, objective=0.9,
              rules=((10.0, 100.0, 2.0),))
    eng = SLOEngine([slo], clock=lambda: t[0])
    # long healthy history fills the slow window with good samples
    for _ in range(100):
        eng.tick()
        t[0] += 1.0
    # outage: fast window saturates quickly, slow window lags
    val[0] = 5.0
    fired_at = None
    for i in range(60):
        eng.tick()
        if fired_at is None and eng.report()["alerts_total"]:
            fired_at = i
        t[0] += 1.0
    rep = eng.report()
    assert fired_at is not None, "sustained outage must alert"
    # burn>2 with budget 0.1 needs bad_frac>0.2 in BOTH windows: the
    # 100-sample slow window requires >20 bad samples, so the alert must
    # arrive later than the fast window alone would allow
    assert fired_at >= 20
    # rising-edge dedupe: one alert despite ~40 more firing ticks
    assert rep["alerts_total"] == 1
    events = [e for e in obs_log.recent(500)
              if e["name"] == "slo_burn_alert"]
    assert len(events) == 1
    assert events[0]["slo"] == "s" and events[0]["burn_fast"] > 2.0


def test_burn_alert_rearms_after_recovery(fresh_obs):
    t = [0.0]
    val = [0.0]
    slo = SLO("s", lambda: val[0], target=1.0, objective=0.5,
              rules=((4.0, 8.0, 1.5),))
    eng = SLOEngine([slo], clock=lambda: t[0])

    def run(n, v):
        val[0] = v
        for _ in range(n):
            eng.tick()
            t[0] += 1.0

    run(10, 0.0)          # healthy baseline
    run(10, 9.0)          # first outage -> alert
    assert eng.report()["alerts_total"] == 1
    run(12, 0.0)          # recovery clears the fast window -> re-arm
    run(10, 9.0)          # second outage -> second alert
    assert eng.report()["alerts_total"] == 2


def test_broken_signal_is_an_error_event_not_an_outage(fresh_obs):
    def boom():
        raise RuntimeError("sensor detached")
    eng = SLOEngine([SLO("s", boom, target=1.0)], clock=lambda: 0.0)
    eng.tick()
    row = eng.report()["slos"][0]
    assert row["samples"] == 0 and row["meeting_target"]
    assert any(e["name"] == "slo_signal_error"
               for e in obs_log.recent(50))


def test_burn_rule_scaling_and_default_catalog(fresh_obs):
    r = BurnRule(300.0, 3600.0, 14.4).scaled(1.0 / 200.0)
    assert r.fast_s == pytest.approx(1.5)
    assert r.slow_s == pytest.approx(18.0)
    assert r.burn == 14.4
    names = {s.name for s in default_slos()}
    assert names == {"query_p99_latency", "freshness_staleness",
                     "certified_psi_error", "degraded_answer_ratio"}


def test_healthz_and_slo_http_endpoints(fresh_obs):
    eng = SLOEngine([SLO("s", lambda: 0.5, target=1.0)],
                    clock=lambda: 0.0)
    eng.tick()
    server = obs.start_http_server(0)     # ephemeral port
    try:
        port = server.server_address[1]

        def get(path):
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}") as r:
                return r.status, json.load(r)
        status, hz = get("/healthz")
        assert status == 200 and hz["status"] == "ok"
        assert hz["metrics_enabled"] and not hz["slo_installed"]
        # no engine installed yet -> /slo is a 404
        with pytest.raises(urllib.error.HTTPError) as ei:
            get("/slo")
        assert ei.value.code == 404
        eng.install()
        try:
            status, doc = get("/slo")
            assert status == 200
            assert doc["slos"][0]["name"] == "s" and doc["ok"]
            assert get("/healthz")[1]["slo_installed"]
        finally:
            eng.uninstall()
    finally:
        server.shutdown()


# --------------------------------------------------------------------- #
# span-stream profiler
# --------------------------------------------------------------------- #
def _span(name, ts, dur, *, id=None, parent=None, thread=0, **attrs):
    rec = dict(name=name, id=id or f"{name}@{ts}", parent=parent,
               depth=0 if parent is None else 1, thread=thread,
               ts=ts, dur=dur)
    if attrs:
        rec["attrs"] = attrs
    return rec


def test_folded_stacks_and_self_time(tmp_path):
    recs = [
        _span("serve", 0.0, 1.0, id="root"),
        _span("engine.run", 0.1, 0.6, id="eng", parent="root",
              backend="reference"),
        _span("engine.run", 0.8, 0.1, id="eng2", parent="root",
              backend="reference"),
    ]
    prof = Profile(recs)
    folded = prof.folded()
    assert folded["serve"] == pytest.approx(0.3)       # 1.0 - 0.6 - 0.1
    key = "serve;engine.run[backend=reference]"
    assert folded[key] == pytest.approx(0.7)
    out = tmp_path / "profile.folded"
    prof.write_folded(str(out))
    assert f"{key} 700000" in out.read_text()          # integer µs lines


def test_self_time_ignores_cross_thread_children():
    recs = [
        _span("async.run", 0.0, 1.0, id="root", thread=0),
        _span("async.step", 0.0, 0.9, id="w", parent="root", thread=1,
              chunk=0),
    ]
    prof = Profile(recs)
    # the worker runs on its own thread: it owns its time, the parent
    # keeps its full wall (it was genuinely busy dispatching/waiting)
    assert prof.folded()["async.run"] == pytest.approx(1.0)
    assert prof.folded()["async.run;async.step[chunk=0]"] \
        == pytest.approx(0.9)


def test_hotspots_carry_dispatch_sync_split():
    recs = [_span("engine.run", 0.0, 1.0, id="e", backend="pallas")]
    recs[0]["dispatch_s"] = 0.7
    recs[0]["sync_s"] = 0.2
    h = Profile(recs).hotspots(1)[0]
    assert h["frame"] == "engine.run[backend=pallas]"
    assert h["dispatch_s"] == pytest.approx(0.7)
    assert h["sync_s"] == pytest.approx(0.2)


def test_critical_path_names_the_bounding_chunk():
    # chunk 1's chain finishes last and dominates wall-clock
    recs = [
        _span("async.step", 0.0, 0.2, id="a0", thread=1, chunk=0,
              epoch=0),
        _span("async.step", 0.0, 0.5, id="b0", thread=2, chunk=1,
              epoch=0),
        _span("async.step", 0.5, 0.5, id="b1", thread=2, chunk=1,
              epoch=1),
        _span("async.step", 0.21, 0.2, id="a1", thread=1, chunk=0,
              epoch=1),
    ]
    cp = Profile(recs).critical_path()
    assert cp.bounding_chunk == 1
    assert cp.length_s == pytest.approx(1.0)
    assert "chunk 1" in cp.describe()


def test_real_async_run_profiles_end_to_end(fresh_obs):
    from repro.asyncexec import AsyncPsiDriver
    from repro.core import heterogeneous
    from repro.graphs import powerlaw_configuration
    g = powerlaw_configuration(300, 1800, seed=3)
    drv = AsyncPsiDriver(g, heterogeneous(300, seed=4), num_chunks=3,
                         tau=2)
    drv.run(tol=1e-6, max_iter=2000)
    prof = Profile.from_tracer(obs.trace.get_tracer())
    assert any(r["name"] == "async.step" for r in prof.records)
    steps = [r for r in prof.records if r["name"] == "async.step"]
    assert all("chunk" in (r.get("attrs") or {}) for r in steps)
    assert any((r.get("attrs") or {}).get("epoch", -1) >= 0
               for r in steps)
    cp = prof.critical_path()
    assert cp.steps and 0.0 < cp.length_s <= cp.wall_s + 1e-9
    assert sum(cp.chunk_share.values()) == pytest.approx(cp.length_s)


# --------------------------------------------------------------------- #
# convergence watch
# --------------------------------------------------------------------- #
def _resolve_record(gaps, *, backend="reference", accepted=0, rejected=0):
    rec = ResolveRecord(backend, "_default", 0, max_points=512)
    for t, g in enumerate(gaps):
        rec.add_point(t, raw=g)
    rec.aitken_accepted = accepted
    rec.aitken_rejected = rejected
    return rec


def test_watch_flags_contraction_drift(fresh_obs):
    w = ConvergenceWatch(baseline=2, rho_drift=0.05)
    healthy = [0.5 ** i for i in range(10)]           # rho 0.5
    for _ in range(2):
        w.observe_record(_resolve_record(healthy))
    assert not w.advice()
    w.observe_record(_resolve_record([0.9 ** i for i in range(10)]))
    adv = w.advice()
    assert adv.sync_sweep and "rho_drift" in adv.reasons


def test_watch_flags_gap_plateau(fresh_obs):
    w = ConvergenceWatch()
    w.observe_record(_resolve_record([1e-3] * 8))
    assert "gap_plateau" in w.advice().reasons


def test_watch_flags_aitken_shift(fresh_obs):
    w = ConvergenceWatch(baseline=2, aitken_shift=0.35)
    for _ in range(2):
        w.observe_record(_resolve_record([], accepted=9, rejected=1))
    w.observe_record(_resolve_record([], accepted=2, rejected=8))
    assert "aitken_shift" in w.advice().reasons


def test_watch_flags_certificate_storm_onset(fresh_obs):
    class Report:
        rejected_certificates = 30
    w = ConvergenceWatch(cert_storm=50, storm_frac=0.5)
    w.observe_report(Report())
    adv = w.advice()
    assert adv.tighten_tau and "cert_storm_onset" in adv.reasons


def test_watch_projects_alpha_across_the_wall(fresh_obs):
    w = ConvergenceWatch(alpha_max=1.0, alpha_horizon=3)
    for a in (0.80, 0.87, 0.94):      # +0.07/step -> 1.15 in 3 steps
        w.observe_alpha(a)
    adv = w.advice()
    assert adv.sync_sweep and "alpha_drift" in adv.reasons
    # flagged BEFORE the wall: last observed alpha still < alpha_max
    assert w.signals[-1].value == pytest.approx(0.94)


def test_watch_ignores_flat_alpha(fresh_obs):
    w = ConvergenceWatch()
    for a in (0.80, 0.80, 0.80, 0.80):
        w.observe_alpha(a)
    assert not w.advice()


def test_advice_latches_and_consume_rearms(fresh_obs):
    w = ConvergenceWatch()
    w.observe_failure("timeout", "attempt 1")
    assert w.advice() and w.advice()          # peek does not consume
    adv = w.consume_advice()
    assert adv.sync_sweep and adv.reasons == ("attempt_failure",)
    assert not w.advice() and not w.consume_advice()


def test_watch_attach_subscribes_to_the_tracker(fresh_obs):
    from repro.obs import convergence as obs_convergence
    w = ConvergenceWatch().attach()
    try:
        tr = obs_convergence.get_tracker()
        rec = tr.begin("reference")
        for t in range(8):
            rec.add_point(t, raw=1e-3)        # flat -> plateau
        tr.finish(rec, iterations=8, gap=1e-3, converged=False)
        assert "gap_plateau" in w.advice().reasons
    finally:
        w.detach()
    fam = fresh_obs.get("psi_watch_signals_total")
    assert sum(ch.value for _, ch in fam.children()) >= 1
    assert any(e["name"] == "watch_anomaly" for e in obs_log.recent(50))


def test_watch_feeds_preemptive_rechunk_into_the_ladder(fresh_obs):
    from repro.asyncexec import AsyncPsiDriver
    from repro.core import heterogeneous
    from repro.graphs import powerlaw_configuration
    from repro.resilience import ResilientResolver
    g = powerlaw_configuration(300, 1800, seed=3)
    drv = AsyncPsiDriver(g, heterogeneous(300, seed=4), num_chunks=3,
                         tau=2)
    w = ConvergenceWatch(cert_storm=50, storm_frac=0.5)

    class Report:
        rejected_certificates = 40
    w.observe_report(Report())                # tighten_tau advice pending
    res = ResilientResolver(drv, tol=1e-6, max_iter=4000, watch=w)
    out = res.resolve()
    assert res.report.preemptions == ["rechunk"]
    assert res.driver.tau == 0                # staleness bound tightened
    assert not out.degraded and out.escalation == "none"
    fam = fresh_obs.get("psi_resilience_preemptions_total")
    assert fam is not None and \
        fam.labels(action="rechunk").value == 1
    # advice was consumed: a second resolve does not re-preempt
    res.resolve()
    assert res.report.preemptions == ["rechunk"]


# --------------------------------------------------------------------- #
# perf-regression gate
# --------------------------------------------------------------------- #
def _bench_doc(cand_wall=1.0, *, n_base=4, env=None, cand_env=None,
               quick=False, cand_quick=None):
    def run(label, wall, environment, q):
        return dict(label=label, quick=q, environment=environment,
                    entries=[dict(graph="powerlaw", backend="reference",
                                  regime=None, n=100, m=500,
                                  dtype="float64", tol=1e-8,
                                  wall_s=wall, matvecs=40,
                                  work_frac=0.5)])
    runs = [run(f"b{i}", 1.0 + 0.01 * i, env or {}, quick)
            for i in range(n_base)]
    runs.append(run("cand", cand_wall,
                    cand_env if cand_env is not None else (env or {}),
                    quick if cand_quick is None else cand_quick))
    return dict(schema=1, runs=runs)


def test_gate_passes_within_noise_and_catches_slowdown():
    assert gate(_bench_doc(1.02))["ok"]
    verdict = gate(_bench_doc(2.1))
    assert not verdict["ok"]
    assert any("powerlaw/reference" in r and "wall_s" in r
               for r in verdict["regressions"])
    row = next(r for r in verdict["rows"]
               if r["metric"] == "wall_s")
    assert row["status"] == "regression" and row["baselines"] == 4


def test_gate_mad_absorbs_one_noisy_baseline():
    doc = _bench_doc(1.05)
    doc["runs"][0]["entries"][0]["wall_s"] = 30.0   # one wild outlier
    verdict = gate(doc)
    assert verdict["ok"], "median/MAD must shrug off a single outlier"


def test_gate_direction_higher_is_better():
    doc = _bench_doc()
    for r in doc["runs"]:
        r["entries"][0]["events_per_s"] = (
            5000.0 if r["label"] != "cand" else 2000.0)
    verdict = gate(doc)
    assert not verdict["ok"]
    assert any("events_per_s" in r for r in verdict["regressions"])


def test_gate_env_and_quick_matching():
    # env mismatch -> no baselines -> skipped, not compared
    doc = _bench_doc(9.0, env={"device_platform": "cpu"},
                     cand_env={"device_platform": "gpu"})
    verdict = gate(doc)
    assert verdict["ok"] and verdict["baselines"] == []
    assert all(r["status"] == "skipped" for r in verdict["rows"])
    # empty env on old runs is a wildcard: still comparable
    doc = _bench_doc(1.0, env={}, cand_env={"device_platform": "cpu"})
    assert len(gate(doc)["baselines"]) == 4
    # quick runs never gate against full runs
    doc = _bench_doc(9.0, cand_quick=True)
    assert gate(doc)["ok"] and gate(doc)["baselines"] == []


def test_inject_slowdown_is_caught_and_original_untouched():
    doc = _bench_doc(1.0)
    slowed = inject_slowdown(doc, factor=2.0)
    assert doc["runs"][-1]["entries"][0]["wall_s"] == 1.0
    assert slowed["runs"][-1]["entries"][0]["wall_s"] == 2.0
    assert gate(doc)["ok"] and not gate(slowed)["ok"]


def test_regress_cli_exit_codes(tmp_path):
    good = tmp_path / "bench.json"
    good.write_text(json.dumps(_bench_doc(1.0)))
    out = tmp_path / "verdict.json"
    assert regress_main(["--json", str(good), "--out", str(out),
                         "--self-check"]) == 0
    verdict = json.loads(out.read_text())
    assert verdict["ok"] and verdict["candidate"] == "cand"
    bad = tmp_path / "bench_bad.json"
    bad.write_text(json.dumps(_bench_doc(3.0)))
    assert regress_main(["--json", str(bad)]) == 1


def test_regress_gates_the_checked_in_trajectory():
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_power_psi.json")
    with open(path) as f:
        doc = json.load(f)
    verdict = gate(doc, quick=bool(
        doc["runs"][-1].get("quick")))
    assert verdict["ok"], verdict["regressions"]
    slowed = inject_slowdown(doc, factor=2.0)
    assert not gate(slowed, quick=bool(
        doc["runs"][-1].get("quick")))["ok"]
