"""Bounded-staleness async executor: certificate math, chunk scheduler,
driver fault tolerance, and the staleness-injection property harness."""
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from repro.asyncexec import (AsyncChunkScheduler, AsyncPsiDriver,
                             ChunkedOperators, RhoEstimator, StalenessBound,
                             certify_gap)
from repro.core import (Activity, HostOperators, PsiService, build_operators,
                        exact_psi, heterogeneous, make_engine,
                        available_backends)
from repro.core.engine import ChunkExtrapolator
from repro.graphs import erdos_renyi, powerlaw_configuration
from repro.graphs.structure import Graph

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                          # dev-only dep
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def platform():
    g = powerlaw_configuration(400, 2400, seed=50)
    act = heterogeneous(g.n, seed=51)
    psi_true, _ = exact_psi(g, act)
    return g, act, psi_true


# --------------------------------------------------------------------- #
# Staleness model + certificate
# --------------------------------------------------------------------- #
def test_certificate_trusts_and_inflates_within_tau():
    bound = StalenessBound(tau=2)
    cert = certify_gap([1e-9] * 4, [5, 4, 5, 5], bound=bound, rho=0.5)
    assert cert.trusted and cert.spread == 1
    # ρ-inflation: one epoch of spread at ρ=0.5 doubles the certified gap
    assert cert.certified_gap == pytest.approx(4e-9 * 2.0)
    assert cert.accepts(1e-7) and not cert.accepts(1e-9)


def test_certificate_rejects_tau_violation():
    """A τ-violating assembly is rejected regardless of its magnitude."""
    cert = certify_gap([1e-16] * 4, [8, 5, 8, 8],
                       bound=StalenessBound(tau=2), rho=0.9)
    assert cert.spread == 3
    assert not cert.trusted
    assert not cert.accepts(1.0)
    # the inflation is still pessimistic (≥ ρ^{-τ})
    assert cert.certified_gap > cert.raw_gap


def test_staleness_bound_validation():
    with pytest.raises(ValueError, match="tau"):
        StalenessBound(tau=-1)
    with pytest.raises(ValueError, match="rho"):
        StalenessBound(tau=1, rho=1.5)
    with pytest.raises(ValueError, match="tau"):
        make_engine("async", tau=-2)


def test_rho_estimator_is_conservative():
    est = RhoEstimator(init=0.9)
    assert est.value == 0.9
    for gap in (1.0, 0.5, 0.3, 0.21):        # ratios 0.5, 0.6, 0.7
        est.update(gap)
    # min of the recent ratios: under-estimating ρ *grows* the ρ^{-σ}
    # inflation, which is the safe direction for the certificate
    assert est.value == pytest.approx(0.5)
    est.update(1e-6)                         # transient collapse clamps
    assert est.value >= 0.05


# --------------------------------------------------------------------- #
# Chunk decomposition: one synchronous sweep == one global iteration
# --------------------------------------------------------------------- #
def test_sync_sweep_is_one_global_iteration(platform):
    g, act, _ = platform
    host = HostOperators.from_graph(g, act)
    chunked = ChunkedOperators(host, 4)
    sched = AsyncChunkScheduler(chunked)
    ops = build_operators(g, act)
    new, raw = sched.sync_sweep(chunked.board0)
    s0 = np.asarray(ops.c)
    s1 = np.asarray(ops.mu * ops.push(jnp.asarray(s0)) + ops.c)
    # host mirror accumulates in f64 before the device cast, so the chunked
    # operands can differ from the all-f32 build by an ulp
    np.testing.assert_allclose(chunked.node_order(new), s1,
                               rtol=1e-6, atol=1e-9)
    assert raw == pytest.approx(float(np.abs(s1 - s0).sum()), rel=1e-4)


# --------------------------------------------------------------------- #
# Async engine: parity + straggler absorption
# --------------------------------------------------------------------- #
def test_async_backend_registered():
    assert "async" in available_backends()


@pytest.mark.parametrize("tau,chunks", [(0, 4), (1, 3), (2, 4), (3, 7)])
def test_async_converges_to_sync_fixed_point(platform, tau, chunks):
    g, act, psi_true = platform
    eng = make_engine("async", graph=g, activity=act,
                      num_chunks=chunks, tau=tau)
    res = eng.run(tol=1e-10)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6
    out = eng.last_run
    assert out.sync_sweeps >= 1              # termination was sync-verified
    # observed pipeline skew never exceeds the bound (+1 for the transient
    # where a τ-ahead chunk publishes before the floor advances)
    assert out.max_staleness <= tau + 1


def test_straggler_absorption(platform):
    """A permanently slow chunk falls behind instead of stalling every
    epoch, and the answer is still the synchronous fixed point."""
    g, act, psi_true = platform
    eng = make_engine(
        "async", graph=g, activity=act, num_chunks=4, tau=2,
        delay_hook=lambda k, e: 0.02 if k == 0 and e <= 8 else 0.0)
    res = eng.run(tol=1e-9)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6
    assert eng.last_run.max_staleness >= 1   # the pipeline actually skewed


def test_async_rejects_accelerate_and_bad_norm():
    with pytest.raises(ValueError, match="Aitken"):
        make_engine("async", accelerate=True)
    from repro.core import ConvergenceCriterion
    with pytest.raises(ValueError, match="l1"):
        make_engine("async", criterion=ConvergenceCriterion(norm="l2"))


def test_async_service_delta_roundtrip(platform):
    """PsiService over the async backend: warm re-solves through the O(Δ)
    patch hooks stay exact."""
    g, act, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend="async",
                     engine_opts=dict(num_chunks=4, tau=2))
    svc.scores()
    u = 9
    svc.update_activity(np.asarray([u]), lam=np.asarray([4.0]))
    lam2 = act.lam.copy()
    lam2[u] = 4.0
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


def test_async_engine_patch_edges_including_regrow(platform):
    """Edge patches land in the touched chunks; overflowing a chunk's
    lane-padded e_max regrows the chunk format and stays exact."""
    g, act, _ = platform
    eng = make_engine("async", graph=g, activity=act, num_chunks=4,
                      tau=2, lane_pad=8)
    prev = eng.run(tol=1e-9)
    e_max_before = eng.chunked.e_max
    rng = np.random.default_rng(3)
    existing = set(zip(g.src.tolist(), g.dst.tolist()))
    pairs = set()
    while len(pairs) < e_max_before + 16:    # force chunk-0 overflow
        s, d = int(rng.integers(0, g.n)), int(rng.integers(0, eng.chunked.q))
        if s != d and (s, d) not in existing:
            pairs.add((s, d))
    src = np.asarray([p[0] for p in sorted(pairs)], np.int32)
    dst = np.asarray([p[1] for p in sorted(pairs)], np.int32)
    assert eng.patch_edges(src, dst) is True
    assert eng.chunked.e_max > e_max_before
    res = eng.run(tol=1e-9, s0=prev.s)
    g2 = Graph(g.n, np.concatenate([g.src, src]),
               np.concatenate([g.dst, dst])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6


def test_midflight_patch_without_drain(platform):
    """An activity patch applied from the epoch callback (pipeline live,
    nothing drained) re-converges to the patched fixed point."""
    g, act, _ = platform
    host = HostOperators.from_graph(g, act)
    chunked = ChunkedOperators(host, 4)
    sched = AsyncChunkScheduler(chunked, bound=StalenessBound(2))
    state = {"applied": False}

    def on_epoch(s, min_epoch):
        if min_epoch >= 2 and not state["applied"]:
            state["applied"] = True
            host.patch_activity(np.asarray([7]), lam=np.asarray([6.0]))
            s.patch_node_arrays()

    out = sched.run(tol=1e-11, epoch_callback=on_epoch)
    assert state["applied"] and out.converged
    lam2 = act.lam.copy()
    lam2[7] = 6.0
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    ops2 = HostOperators.from_graph(g, Activity(lam2, act.mu)).to_device()
    psi = np.asarray(ops2.psi_epilogue(
        jnp.asarray(chunked.node_order(out.s))))
    assert np.abs(psi - psi_true).max() <= 1e-7


# --------------------------------------------------------------------- #
# AsyncPsiDriver: checkpoint/restart with epoch vectors, elastic rechunk,
# straggler forensics
# --------------------------------------------------------------------- #
def test_async_driver_checkpoint_restart(platform):
    g, act, psi_true = platform
    with tempfile.TemporaryDirectory() as d:
        drv = AsyncPsiDriver(g, act, num_chunks=4, tau=1, ckpt_dir=d,
                             ckpt_every=2)
        rep = drv.run(tol=1e-7, fail_hook=lambda t: t in (3, 6))
        assert rep.restarts == 2
        assert rep.gap <= 1e-7
        assert np.abs(rep.psi - psi_true).max() <= 1e-6
        # the checkpoint carries the epoch vector (async-exact restart)
        from repro.ckpt import checkpoint
        step = checkpoint.latest_step(d)
        data = checkpoint.restore(
            d, step, dict(s=np.zeros(drv.chunked.n_pad, np.float32),
                          epochs=np.zeros(4, np.int64), it=np.int64(0)))
        assert data["epochs"].shape == (4,)
        assert int(data["epochs"].min()) >= 1


def test_async_driver_rechunk_warm(platform):
    """Elastic re-chunk (the remesh analogue): the board carries across a
    chunk-count change and the new pipeline resumes warm."""
    g, act, _ = platform
    drv = AsyncPsiDriver(g, act, num_chunks=4, tau=2)
    drv.run(tol=1e-3)                        # partial progress
    warm = drv.rechunk(6).run(tol=1e-8)
    cold = AsyncPsiDriver(g, act, num_chunks=6, tau=2).run(tol=1e-8)
    assert warm.iterations < cold.iterations
    assert np.abs(warm.psi - cold.psi).max() <= 1e-6


def test_async_driver_slow_chunk_forensics(platform):
    """slow_chunk_events carry the measured duration *and* the deadline it
    exceeded — not just the chunk index (DriverReport satellite)."""
    g, act, _ = platform
    drv = AsyncPsiDriver(
        g, act, num_chunks=4, tau=2, deadline_factor=3.0,
        delay_hook=lambda k, e: 0.05 if k == 2 and e >= 5 else 0.0)
    rep = drv.run(tol=1e-7)
    assert rep.chunk_durations                 # every step's duration kept
    assert rep.slow_chunk_events
    # the delayed chunk must be flagged (thread-timing noise may flag
    # other chunks too — the forensics, not the order, are the contract)
    slow_2 = [e for e in rep.slow_chunk_events if e.chunk == 2]
    assert slow_2 and all(e.duration > e.deadline > 0.0 for e in slow_2)
    assert max(e.duration for e in slow_2) >= 0.05
    assert set(rep.slow_chunks) == {e.chunk for e in rep.slow_chunk_events}
    assert rep.max_staleness >= 1 and rep.tau == 2


def test_chunk_extrapolator_epoch_guard():
    """Aitken jumps only fire on same-epoch endpoint pairs."""
    def feed(spread):
        ex = ChunkExtrapolator(1e-12)
        for k in range(1, 8):                # clean geometric contraction
            s_in = np.full(4, 1.0 - 0.5 ** (k - 1))
            s_out = np.full(4, 1.0 - 0.5 ** k)
            ex.advance(s_in, s_out, gap=0.5 ** k, epoch_spread=spread)
        return ex.jumps

    assert feed(0) >= 1                      # consistent pairs extrapolate
    assert feed(1) == 0                      # mixed-epoch pairs never jump


# --------------------------------------------------------------------- #
# Mid-flight streaming: StreamIngestor patches land through the
# generation-guarded hooks while chunks are in flight (PR satellite)
# --------------------------------------------------------------------- #
def _random_event_log(g, seed: int, count: int = 60):
    """Posts/reposts/follows mixed, monotone timestamps, seeded."""
    from repro.stream import Follow, Post, ReplayLog, Repost
    rng = np.random.default_rng(seed)
    events, t = [], 0.0
    for _ in range(count):
        t += float(rng.random())
        kind = int(rng.integers(0, 4))
        if kind < 2:
            events.append(Post(t, int(rng.integers(0, g.n))))
        elif kind == 2:
            events.append(Repost(t, int(rng.integers(0, g.n))))
        else:
            s, d = (int(x) for x in rng.integers(0, g.n, 2))
            if s != d:
                events.append(Follow(t, s, d))
    return ReplayLog.from_events(events)


def test_stream_ingestor_pumps_midflight(platform):
    """Events pumped from the driver's epoch_hook while the pipeline is
    live reach the same fixed point as applying them all up front."""
    from repro.stream import FreshnessPolicy, StreamIngestor
    g, act, _ = platform
    log = _random_event_log(g, seed=77, count=80)
    drv = AsyncPsiDriver(g, act, num_chunks=4, tau=2)
    ing = StreamIngestor(drv, half_life=30.0,
                         policy=FreshnessPolicy(coalesce=8,
                                                resolve_every=None))
    ing.attach(log)
    pumped = {"mid": 0}

    def feed(min_epoch):
        pumped["mid"] += ing.pump(8)

    rep = drv.run(tol=1e-10, epoch_hook=feed)
    assert pumped["mid"] > 0                   # patches landed mid-flight
    if not ing.exhausted:                      # converged before the tail
        while ing.pump(64):
            pass
        rep = drv.run(tol=1e-10, warm=True)
    ref = make_engine("reference", graph=drv.host.graph(),
                      activity=drv.host.activity()).run(tol=1e-10)
    assert np.abs(rep.psi - np.asarray(ref.psi)).max() <= 1e-6


# --------------------------------------------------------------------- #
# Property harness: random bounded staleness ≤ τ still reaches the sync
# fixed point; τ-violating assemblies are rejected (PR satellite)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @given(st.integers(0, 9_999), st.integers(1, 3))
    @settings(max_examples=8, deadline=None)
    def test_bounded_stale_partials_reach_sync_fixed_point(seed, tau):
        g = erdos_renyi(60, 240, seed=seed % 100)
        act = heterogeneous(g.n, seed=seed % 97)
        ref = make_engine("reference", graph=g,
                          activity=act).run(tol=1e-11)
        rng = np.random.default_rng(seed)

        def lag_hook(reader, neighbor, epochs):
            return int(rng.integers(0, tau + 1))   # random staleness ≤ τ

        eng = make_engine("async", graph=g, activity=act, num_chunks=3,
                          tau=tau, read_hook=lag_hook)
        res = eng.run(tol=1e-11)
        assert bool(res.converged)
        assert np.abs(np.asarray(res.psi)
                      - np.asarray(ref.psi)).max() <= 1e-6

    @given(st.integers(0, 9_999), st.integers(0, 3))
    @settings(max_examples=6, deadline=None)
    def test_midflight_interleave_matches_upfront_fixed_point(seed, tau):
        """PR satellite: interleaving StreamIngestor patches with
        AsyncPsiDriver chunks at any staleness ≤ τ reaches the same fixed
        point as applying every event up front."""
        from repro.stream import FreshnessPolicy, StreamIngestor
        g = erdos_renyi(48, 200, seed=seed % 37)
        act = heterogeneous(g.n, seed=seed % 29)
        log = _random_event_log(g, seed=seed, count=50)
        rng = np.random.default_rng(seed + 1)

        def lag_hook(reader, neighbor, epochs):
            return int(rng.integers(0, tau + 1))   # random staleness ≤ τ

        drv = AsyncPsiDriver(g, act, num_chunks=3, tau=tau,
                             read_hook=lag_hook)
        ing = StreamIngestor(drv, half_life=25.0,
                             policy=FreshnessPolicy(coalesce=8,
                                                    resolve_every=None))
        ing.attach(log)
        rep = drv.run(tol=1e-11, epoch_hook=lambda e: ing.pump(8))
        if not ing.exhausted:
            while ing.pump(64):
                pass
            rep = drv.run(tol=1e-11, warm=True)
        # the up-front oracle: every event applied, then one cold solve
        ref = make_engine("reference", graph=drv.host.graph(),
                          activity=drv.host.activity()).run(tol=1e-11)
        assert np.abs(rep.psi - np.asarray(ref.psi)).max() <= 1e-6

    @given(st.integers(0, 3), st.integers(1, 6), st.integers(0, 20))
    @settings(max_examples=20, deadline=None)
    def test_certificate_rejects_any_tau_violation(tau, excess, base_epoch):
        """For every τ, any epoch assembly whose spread exceeds τ is
        rejected; any within-τ assembly is trusted and ρ-inflated."""
        bound = StalenessBound(tau=tau)
        bad = certify_gap(
            [1e-12] * 3, [base_epoch + tau + excess, base_epoch,
                          base_epoch + 1], bound=bound, rho=0.8)
        assert not bad.trusted and not bad.accepts(1.0)
        ok = certify_gap([1e-12] * 3,
                         [base_epoch + tau, base_epoch, base_epoch],
                         bound=bound, rho=0.8)
        assert ok.trusted
        assert ok.certified_gap == pytest.approx(
            3e-12 * 0.8 ** (-float(tau)))
