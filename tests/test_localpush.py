"""Local residual-push solver: push invariant, residual certificates,
certified top-k early stop, O(Δ) warm reseeds, jit frontier parity, and
the certified serving/freshness integration."""
import numpy as np
import pytest

from repro.core import (Activity, HostOperators, PsiService, exact_psi,
                        heterogeneous, make_engine)
from repro.graphs import powerlaw_configuration
from repro.graphs.structure import Graph
from repro.localpush import (a_norm, cert_scale, certify_top_k, cold_state,
                             psi_value, push_scalar, push_until, reseed_state)
from repro.localpush import push as push_mod
from repro.localpush import warm
from repro.stream import FreshnessReport, Post, RateEstimator, Repost


@pytest.fixture(scope="module")
def platform():
    g = powerlaw_configuration(400, 2600, seed=5)
    act = heterogeneous(g.n, seed=6)
    psi_true, s_true = exact_psi(g, act)
    return g, act, psi_true, s_true


def _host(g, act):
    return HostOperators.from_graph(g, act)


def _check_invariant(host, state):
    """r and p must satisfy r = c + μ⊙p − x with p derived from x."""
    fresh = reseed_state(host, state.x)
    np.testing.assert_allclose(state.p, fresh.p, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(state.r, fresh.r, rtol=1e-9, atol=1e-12)


# --------------------------------------------------------------------- #
# Core push: scalar oracle, vectorized rounds, certificates
# --------------------------------------------------------------------- #
def test_scalar_oracle_and_vectorized_rounds_agree(platform):
    g, act, psi_true, _ = platform
    host = _host(g, act)
    tol_r = 1e-11
    st_scalar, pushes, _ = push_scalar(host, tol_r=tol_r)
    assert pushes > 0
    st_round = cold_state(host)
    push_until(host, st_round, tol_r=tol_r)
    bound = cert_scale(host) * tol_r
    for st in (st_scalar, st_round):
        _check_invariant(host, st)
        assert np.abs(psi_value(host, st) - psi_true).max() <= bound


def test_each_push_contracts_the_residual(platform):
    g, act, _, _ = platform
    host = _host(g, act)
    alpha = a_norm(host)
    assert 0.0 < alpha < 1.0
    st = cold_state(host)
    for _ in range(50):
        before = push_mod.l1(st.r)
        nodes, _ = push_mod.push_round(host, st)
        if nodes.size == 0:
            break
        assert push_mod.l1(st.r) < before + 1e-15


def test_certificate_bounds_true_error_every_run(platform):
    """The acceptance invariant: on every recorded run the certificate is
    ≥ the true |ψ_exact − ψ̂|∞ of the float64 host ψ it covers."""
    g, act, psi_true, _ = platform
    eng = make_engine("push", graph=g, activity=act)
    for tol in (1e-4, 1e-7, 1e-10):
        res = eng.run(tol=tol)
        cert = eng.psi_error_bound()
        assert cert is not None and np.isfinite(cert)
        true_err = np.abs(eng.last_psi_host - psi_true).max()
        assert true_err <= cert


def test_certified_top_k_matches_exact(platform):
    g, act, psi_true, _ = platform
    eng = make_engine("push", graph=g, activity=act)
    res, cert = eng.run_top_k(10, tol=1e-10)
    assert cert is not None and cert.certified
    exact_top = set(np.argsort(-psi_true, kind="stable")[:10].tolist())
    assert set(cert.indices.tolist()) == exact_top
    # early certified stop does real work savings vs the full solve
    assert int(res.iterations) <= int(eng.run(tol=1e-10).iterations)


def test_certify_top_k_edge_cases():
    psi = np.asarray([0.5, 0.4, 0.39, 0.1])
    wide = certify_top_k(psi, 1, err_bound=0.04)   # margin 0.1 > 2·0.04
    assert wide.certified
    tight = certify_top_k(psi, 2, err_bound=0.01)  # margin 0.01 < 2·0.01
    assert not tight.certified
    nobound = certify_top_k(psi, 2, err_bound=None)
    assert not nobound.certified                   # honest: no certificate
    assert nobound.indices.tolist() == [0, 1]      # indices still served
    whole = certify_top_k(psi, 4, err_bound=0.5)
    assert whole.certified and np.isinf(whole.margin)


# --------------------------------------------------------------------- #
# O(Δ) warm reseeds: the invariant survives interleaved patches
# --------------------------------------------------------------------- #
def test_invariant_and_parity_after_interleaved_patches(platform):
    g, act, _, _ = platform
    host = _host(g, act)
    st = cold_state(host)
    push_until(host, st, tol_r=1e-9)

    # activity patch
    users = np.asarray([3, 17, 99])
    lam = np.asarray([2.0, 0.7, 1.3])
    warm.apply_activity_patch(host, st, users, lam=lam, mu=None)
    _check_invariant(host, st)
    # edge insert (incl. one duplicate of an existing edge — filtered)
    add_s = np.asarray([0, 5, int(g.src[0])], np.int32)
    add_d = np.asarray([30, 31, int(g.dst[0])], np.int32)
    warm.apply_edge_insert(host, st, add_s, add_d)
    _check_invariant(host, st)
    # edge remove (incl. one absent tombstone — ignored)
    rm_s = np.asarray([0, 7], np.int32)
    rm_d = np.asarray([30, (int(g.dst[7]) + 1) % g.n], np.int32)
    warm.apply_edge_remove(host, st, rm_s, rm_d)
    _check_invariant(host, st)

    # re-push and compare against a from-scratch exact solve
    push_until(host, st, tol_r=1e-12)
    lam2 = act.lam.copy()
    lam2[users] = lam
    g1 = Graph(g.n, np.concatenate([g.src, add_s]),
               np.concatenate([g.dst, add_d])).dedup()
    keep = ~np.isin(g1.src.astype(np.int64) * g1.n + g1.dst,
                    rm_s.astype(np.int64) * g1.n + rm_d)
    g2 = Graph(g.n, g1.src[keep], g1.dst[keep])
    psi_true, _ = exact_psi(g2, Activity(lam2, act.mu))
    assert np.abs(psi_value(host, st) - psi_true).max() <= 1e-9


def test_patch_reseed_residual_is_local(platform):
    """An activity patch creates residual only on the affected subgraph."""
    g, act, _, _ = platform
    host = _host(g, act)
    st = cold_state(host)
    push_until(host, st, tol_r=1e-13)
    base_r = np.abs(st.r).max()
    # a lightly-followed user: the affected set is them plus the leaders of
    # their few followers — a small neighborhood, not the graph
    indeg = np.bincount(g.dst, minlength=g.n)
    u = int(np.flatnonzero(indeg == max(1, indeg[indeg > 0].min()))[0])
    warm.apply_activity_patch(host, st, np.asarray([u]),
                              lam=np.asarray([act.lam[u] * 2.0]), mu=None)
    hot = np.abs(st.r) > 100 * max(base_r, 1e-300)
    assert 0 < hot.sum() < 0.2 * g.n


def test_engine_warm_patch_locality_and_savings():
    """The headline: a 0.1% dirty warm certified-top-k resolve touches a
    small fraction of the graph and beats the cold solve's work."""
    g = powerlaw_configuration(2000, 9000, seed=7)
    act = heterogeneous(g.n, seed=8)
    eng = make_engine("push", graph=g, activity=act)
    cold = eng.run(tol=1e-10)
    cold_work = eng.last_run_stats["edge_work"]
    rng = np.random.default_rng(0)
    users = rng.choice(g.n, size=max(1, g.n // 1000), replace=False)
    eng.patch_activity(users, lam=act.lam[users] * 1.5)
    assert eng.psi_error_bound() is None     # patch invalidated the cert
    # certified top-k warm resolve: stops at rank separation, so the push
    # stays in the dirty neighborhood instead of diffusing graph-wide
    res, cert = eng.run_top_k(20, tol=1e-10, s0=cold.s)
    stats = eng.last_run_stats
    assert stats["reseed_matvecs"] == 0      # identity handle: no reseed
    assert cert.certified
    assert stats["touched_frac"] < 0.5
    assert stats["edge_work"] < cold_work
    lam2 = act.lam.copy()
    lam2[users] = act.lam[users] * 1.5
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert set(cert.indices.tolist()) == \
        set(np.argsort(-psi_true, kind="stable")[:20].tolist())
    # driving on to the full tolerance from the same handle stays exact
    eng.run(tol=1e-10, s0=res.s)
    assert np.abs(eng.last_psi_host - psi_true).max() <= eng.psi_error_bound()


# --------------------------------------------------------------------- #
# jit frontier mode
# --------------------------------------------------------------------- #
def test_jit_frontier_parity(platform):
    g, act, psi_true, _ = platform
    eng = make_engine("push", graph=g, activity=act, frontier="jit",
                      frontier_size=64)
    res = eng.run(tol=1e-6)
    assert bool(res.converged)
    # the certificate covers the float64 host ψ (verified after the
    # compiled phase), never raw device state
    assert np.abs(eng.last_psi_host - psi_true).max() <= eng.psi_error_bound()
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-5


def test_jit_frontier_invalidated_by_edge_patch(platform):
    g, act, _, _ = platform
    eng = make_engine("push", graph=g, activity=act, frontier="jit")
    eng.run(tol=1e-6)
    assert eng._fops is not None
    eng.patch_edges(np.asarray([0]), np.asarray([13]))
    assert eng._fops is None                 # padded leader table regrows
    g2 = Graph(g.n, np.concatenate([g.src, [0]]),
               np.concatenate([g.dst, [13]])).dedup()
    psi_true, _ = exact_psi(g2, act)
    eng.run(tol=1e-8)
    assert np.abs(eng.last_psi_host - psi_true).max() <= 1e-6


# --------------------------------------------------------------------- #
# Engine construction contracts
# --------------------------------------------------------------------- #
def test_push_engine_validates_options():
    with pytest.raises(ValueError, match="l1"):
        from repro.core import ConvergenceCriterion
        make_engine("push", criterion=ConvergenceCriterion(norm="linf"))
    with pytest.raises(ValueError, match="accelerate"):
        make_engine("push", accelerate=True)
    with pytest.raises(ValueError, match="frontier"):
        make_engine("push", frontier="heap")
    with pytest.raises(ValueError, match="bucket_ratio"):
        make_engine("push", bucket_ratio=0.0)


def test_push_engine_rejects_lambda_free_feed():
    """α ≥ 1 (a feed with zero λ mass) has no finite certificate."""
    g = Graph(3, np.asarray([0, 1]), np.asarray([2, 2]))
    # the followed leader never posts (λ=0, μ>0): its followers' feeds
    # carry zero λ mass, so ‖M‖₁ = 1 and the certificate is vacuous
    act = Activity(np.asarray([1.0, 1.0, 0.0]), np.asarray([1.0, 1.0, 1.0]))
    with pytest.raises(ValueError, match="α"):
        make_engine("push", graph=g, activity=act)


# --------------------------------------------------------------------- #
# Serving integration: PsiService.top_k_certified
# --------------------------------------------------------------------- #
def test_service_top_k_certified_early_stop_then_resolve(platform):
    g, act, psi_true, _ = platform
    svc = PsiService(g, act, tol=1e-10, backend="push")
    svc.scores()
    u = int(np.argsort(-psi_true)[5])
    svc.update_activity(np.asarray([u]), lam=np.asarray([act.lam[u] * 1.2]),
                        resolve=False)
    cert = svc.top_k_certified(10)
    assert cert.certified
    lam2 = act.lam.copy()
    lam2[u] = act.lam[u] * 1.2
    psi2, _ = exact_psi(g, Activity(lam2, act.mu))
    assert set(cert.indices.tolist()) == \
        set(np.argsort(-psi2, kind="stable")[:10].tolist())
    # the early stop left scores only err_bound-accurate; resolve restores
    # the full contract and subsequent reads serve the tight fixed point
    svc.resolve()
    assert np.abs(svc.scores() - psi2).max() <= 1e-6


def test_service_noncertifying_backend_is_honest(platform):
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend="reference")
    cert = svc.top_k_certified(5)
    assert not cert.certified                # no residual bound to certify
    assert cert.err_bound is None
    assert cert.indices.shape == (5,)        # indices still served


def test_ranking_cache_bound_inflated_for_cast_psi(platform):
    """The f32 served copy adds a dtype-cast term on top of the float64
    certificate — the cache must not claim the raw bound for it."""
    from repro.core.incremental import RankingCache
    g, act, _, _ = platform
    eng = make_engine("push", graph=g, activity=act)
    res = eng.run(tol=1e-10)
    raw = eng.psi_error_bound()
    cache = RankingCache(np.asarray(res.psi), err_bound=raw)  # f32 copy
    cert = cache.top_k_certified(3)
    eps_term = np.finfo(np.float32).eps * np.abs(np.asarray(res.psi)).max()
    assert cert.err_bound >= raw + 0.5 * eps_term


# --------------------------------------------------------------------- #
# Freshness: certified staleness bound (satellite)
# --------------------------------------------------------------------- #
def _report(**kw):
    base = dict(event_time=1.0, resolve_time=1.0, events_total=10,
                events_buffered=0, events_unresolved=0, dirty_users=0,
                dirty_mass=0.0, resolves=1)
    base.update(kw)
    return FreshnessReport(**base)


def test_freshness_certify_max_psi_error():
    assert _report(psi_error_bound=1e-8).certify(max_psi_error=1e-6)
    assert not _report(psi_error_bound=1e-4).certify(max_psi_error=1e-6)
    # an uncertified ranking can never satisfy a certificate demand
    assert not _report(psi_error_bound=None).certify(max_psi_error=1e-6)
    assert _report(psi_error_bound=None).certify(max_events=5)


def test_ingestor_reports_push_certificate(platform):
    from repro.stream import StreamIngestor
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend="push")
    ing = StreamIngestor(svc)
    ing.ingest([Post(0.5, 3), Repost(0.8, 7)], resolve_at_end=True)
    rep = ing.freshness()
    assert rep.events_unresolved == 0
    assert rep.psi_error_bound is not None
    assert rep.certify(max_psi_error=rep.psi_error_bound * 2)
    # ingest on top of the certified solve → the bound must not outlive it
    ing.submit(Post(1.5, 4))
    rep2 = ing.freshness()
    assert rep2.events_unresolved == 1
    assert rep2.psi_error_bound is None
    assert not rep2.certify(max_psi_error=1.0)


# --------------------------------------------------------------------- #
# Estimator clock consistency (satellite)
# --------------------------------------------------------------------- #
def test_pending_mass_default_matches_drain():
    """pending_mass() and drain() resolve the same default instant, so the
    probe's answer equals the mass the very next drain reports."""
    est = RateEstimator(8, half_life=4.0)
    for t, u in [(0.5, 1), (1.0, 1), (1.5, 3), (2.0, 5)]:
        est.observe_post(t, u)
        est.observe_repost(t + 0.1, u)
    probe = est.pending_mass()
    users, lam, mu, drained = est.drain()
    assert probe == pytest.approx(drained, rel=0, abs=0)
    assert est.pending_mass() == 0.0
