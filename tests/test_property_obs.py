"""Hypothesis property tests for the obs histogram quantile
interpolation (``repro.obs.metrics``): monotonicity in q, min/max
tightening at the endpoints, and exactness on degenerate data."""
import pytest

pytest.importorskip("hypothesis", reason="dev-only dep; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.obs.metrics import MetricsRegistry

obs_values = st.lists(
    st.floats(min_value=1e-9, max_value=1e3, allow_nan=False),
    min_size=1, max_size=64)


def _observed(values):
    reg = MetricsRegistry()
    h = reg.histogram("q_seconds", "test")
    for v in values:
        h.observe(v)
    return h


@given(obs_values, st.floats(min_value=0.0, max_value=1.0),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=80, deadline=None)
def test_quantile_monotone_and_bounded(values, q1, q2):
    h = _observed(values)
    lo, hi = sorted((q1, q2))
    a, b = h.quantile(lo), h.quantile(hi)
    assert a <= b + 1e-12, "quantile must be monotone in q"
    assert min(values) - 1e-12 <= a and b <= max(values) + 1e-12


@given(obs_values)
@settings(max_examples=80, deadline=None)
def test_quantile_endpoints_are_exact_min_max(values):
    h = _observed(values)
    assert h.quantile(0.0) == min(values)
    assert h.quantile(1.0) == max(values)


@given(st.floats(min_value=1e-9, max_value=1e3, allow_nan=False),
       st.integers(min_value=1, max_value=32),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=80, deadline=None)
def test_quantile_exact_on_degenerate_data(value, count, q):
    """All observations equal: every quantile is that exact value —
    min/max tightening must beat bucket-edge interpolation."""
    h = _observed([value] * count)
    assert h.quantile(q) == value
